"""Fault-tolerant execution loop: checkpoint/restart with injected faults.

At 1000+ node scale the mean time between node failures drops below job
length, so the control plane must treat "a step died" as a normal event.
This module provides the single-controller version of that logic (the same
state machine a multi-controller launcher runs per slice):

* :class:`FaultInjector` — deterministic fault schedule for tests/demos
  (raise at given steps, once each), standing in for hardware failures.
* :func:`run_with_restarts` — drives ``step_fn`` from the last checkpoint,
  catching faults, restoring state, and replaying.  Because the data
  pipeline is step-addressable (``repro.data``) and checkpoints are atomic,
  recovery is *bit-exact*: the restarted trajectory equals the fault-free
  one (asserted in tests/test_fault.py).
"""
from __future__ import annotations

import logging
from typing import Any, Callable, Dict, Iterable, Optional, Set, Tuple

from repro.serve.clock import resolve_clock

log = logging.getLogger("repro.runtime")


class SimulatedFault(RuntimeError):
    """Stands in for XlaRuntimeError / host loss in the CPU simulation."""


class FaultInjector:
    def __init__(self, fail_at_steps: Iterable[int] = ()):  # each fires once
        self._pending: Set[int] = set(fail_at_steps)
        self.fired: list = []

    def check(self, step: int):
        if step in self._pending:
            self._pending.discard(step)
            self.fired.append(step)
            raise SimulatedFault(f"injected fault at step {step}")


def run_with_restarts(
    *,
    init_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Tuple[Any, Dict[str, float]]],
    n_steps: int,
    ckpt_manager=None,
    ckpt_every: int = 0,
    restore_fn: Optional[Callable[[int, Any], Any]] = None,
    max_restarts: int = 10,
    on_metrics: Optional[Callable[[int, Dict[str, float]], None]] = None,
    clock: Optional[Callable[[], float]] = None,
) -> Dict[str, Any]:
    """Run ``n_steps`` of ``step_fn`` with checkpoint/restart recovery.

    ``step_fn(state, step)`` may raise (fault); the loop then restores from
    the newest checkpoint (via ``restore_fn(step, state_template)`` if
    given, else ``ckpt_manager.restore``) and replays from there.  Returns
    summary: final state, per-step metrics, restart count, wall time.

    ``clock`` follows the serving stack's injected-clock discipline
    (``repro.serve.clock``): ``wall_s`` is measured on it, so tests can
    run the whole recovery loop on a virtual clock.  ``None`` uses the
    sanctioned ambient wall clock.
    """
    clock = resolve_clock(clock)
    t0 = clock()
    state = init_state()
    start = 0
    if ckpt_manager is not None:
        last = ckpt_manager.latest_step()
        if last is not None:
            state = _restore(ckpt_manager, restore_fn, last, state)
            start = last + 1
            log.info("resuming from checkpoint step %d", last)

    metrics_hist: Dict[int, Dict[str, float]] = {}
    restarts = 0
    step = start
    while step < n_steps:
        try:
            state, metrics = step_fn(state, step)
            metrics_hist[step] = {k: float(v) for k, v in metrics.items()}
            if on_metrics:
                on_metrics(step, metrics_hist[step])
            if ckpt_manager is not None and ckpt_every and (step + 1) % ckpt_every == 0:
                ckpt_manager.save(step, state, metadata={"step": step})
            step += 1
        except Exception as e:  # noqa: BLE001 — any step failure is recoverable
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(f"exceeded max_restarts={max_restarts}") from e
            log.warning("step %d failed (%s); restart %d", step, e, restarts)
            if ckpt_manager is not None:
                last = ckpt_manager.latest_step()
                if last is not None:
                    state = _restore(ckpt_manager, restore_fn, last, state)
                    step = last + 1
                    continue
            # no checkpoint yet: restart from scratch
            state = init_state()
            step = 0
    return {
        "state": state,
        "metrics": metrics_hist,
        "restarts": restarts,
        "wall_s": clock() - t0,
    }


def _restore(ckpt_manager, restore_fn, step: int, state_template):
    if restore_fn is not None:
        return restore_fn(step, state_template)
    restored, _ = ckpt_manager.restore(step, state_template)
    return restored
