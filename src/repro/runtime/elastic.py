"""Elastic scaling: resume a checkpoint on a different mesh.

Checkpoints store full logical arrays (``repro.checkpoint``), so scaling is
re-*sharding*, not re-*assembly*: build the new mesh, resolve the sharding
rule table against it, and device_put every leaf.  The train step is then
re-jitted for the new topology — GSPMD emits the new collective schedule
automatically.  What the launcher must get right (and what this module +
tests pin down):

* param/optimizer leaves keep their logical shapes — any (data, model)
  re-factorization is legal;
* the *global batch* is preserved by default so optimization dynamics don't
  change when pods come/go (per-device batch grows); pass a new
  ``global_batch`` explicitly to trade that off;
* data order stays aligned because the pipeline is step-addressable.
"""
from __future__ import annotations

from typing import Any, Tuple

from repro.checkpoint import CheckpointManager
from repro.parallel.sharding import param_specs


def elastic_restore(
    ckpt_manager: CheckpointManager,
    step: int,
    template: Any,
    new_mesh,
    spec_fn=param_specs,
) -> Tuple[Any, dict]:
    """Restore checkpoint ``step`` re-sharded for ``new_mesh``.

    ``template``: pytree of arrays/ShapeDtypeStructs defining the structure.
    ``spec_fn(template, mesh)`` resolves the sharding tree (defaults to the
    parameter rule table; pass a custom fn for full train states).
    """
    shardings = spec_fn(template, new_mesh)
    return ckpt_manager.restore(step, template, shardings=shardings)
