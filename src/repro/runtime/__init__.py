from repro.runtime.fault import FaultInjector, SimulatedFault, run_with_restarts
from repro.runtime.elastic import elastic_restore

__all__ = ["FaultInjector", "SimulatedFault", "run_with_restarts", "elastic_restore"]
