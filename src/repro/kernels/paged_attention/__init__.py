from repro.kernels.paged_attention.ops import (
    dense_attention_decode, paged_attention_decode, paged_attention_prefill,
)

__all__ = [
    "dense_attention_decode", "paged_attention_decode", "paged_attention_prefill",
]
