"""Pure-jnp oracle: the gathered-view computation the kernel replaces.

Deliberately written as gather-then-mask (``pool[table]`` -> dense
logical view -> masked softmax): the kernel must be bit-compatible with
the memory-hungry formulation it optimizes away.
"""
from __future__ import annotations

import jax.numpy as jnp


def _gather(pool, table, scale=None):
    """pool [n_blocks, KVH, bs, hd], table [B, W] -> [B, KVH, W*bs, hd].

    ``scale`` [KVH] dequantizes int8 pools into exactly the dense
    materialized view the fused kernel never builds.
    """
    b, w = table.shape
    g = pool[table]  # [B, W, KVH, bs, hd]
    out = jnp.moveaxis(g, 2, 1).reshape(b, pool.shape[1], -1, pool.shape[3])
    if scale is not None:
        out = out.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)[None, :, None, None]
    return out


def _softcap(s, softcap):
    return jnp.tanh(s / softcap) * softcap if softcap > 0 else s


def _masked_attn(qg, k, v, mask, scale, softcap):
    """qg [B,KVH,G,Sq,hd], k/v [B,KVH,L,hd], mask [B,Sq,L] -> [B,KVH,G,Sq,hd]."""
    qg, k, v = (x.astype(jnp.float32) for x in (qg, k, v))
    s = _softcap(jnp.einsum("bhgsd,bhld->bhgsl", qg, k) * scale, softcap)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[:, None, None], p, 0.0)
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhgsl,bhld->bhgsd", p / denom, v)


def paged_decode_ref(q, k_pool, v_pool, table, kv_len, *, softcap=0.0,
                     k_scale=None, v_scale=None):
    """q [B, H, hd] -> [B, H, hd] (fp32): keys at positions >= kv_len[b]
    are invisible; kv_len == 0 yields zeros (matching the kernel)."""
    b, h, hd = q.shape
    kvh = k_pool.shape[1]
    k = _gather(k_pool, table, k_scale)
    v = _gather(v_pool, table, v_scale)
    mask = jnp.arange(k.shape[2])[None, None] < kv_len[:, None, None]  # [B,1,L]
    qg = q.reshape(b, kvh, h // kvh, 1, hd)
    o = _masked_attn(qg, k, v, mask, hd ** -0.5, softcap)
    return jnp.where(kv_len[:, None, None] > 0, o.reshape(b, h, hd), 0.0)


def paged_prefill_ref(q, k_pool, v_pool, table, start, *, softcap=0.0,
                      k_scale=None, v_scale=None):
    """q [B, H, S, hd] -> [B, H, S, hd] (fp32): causal against absolute
    positions ``start[b] + i`` over the gathered context view."""
    b, h, s, hd = q.shape
    kvh = k_pool.shape[1]
    k = _gather(k_pool, table, k_scale)
    v = _gather(v_pool, table, v_scale)
    q_pos = start[:, None] + jnp.arange(s)[None]  # [B, S]
    mask = q_pos[:, :, None] >= jnp.arange(k.shape[2])[None, None]  # [B,S,L]
    qg = q.reshape(b, kvh, h // kvh, s, hd)
    return _masked_attn(qg, k, v, mask, hd ** -0.5, softcap).reshape(b, h, s, hd)


def dense_decode_ref(q, k, v, kv_len, *, softcap=0.0):
    """q [B, H, hd], k/v [B, KVH, S, hd] -> [B, H, hd] (fp32)."""
    b, h, hd = q.shape
    kvh = k.shape[1]
    mask = jnp.arange(k.shape[2])[None, None] < kv_len[:, None, None]
    qg = q.reshape(b, kvh, h // kvh, 1, hd)
    o = _masked_attn(qg, k, v, mask, hd ** -0.5, softcap)
    return jnp.where(kv_len[:, None, None] > 0, o.reshape(b, h, hd), 0.0)
