"""Pallas kernel: gather-free paged attention over the serve engine's KV pool.

The serve engine's decode hot path used to *materialize* each slot's
logical cache every step — ``pool[table]`` gathers ``[B, W, n_kv, bs, hd]``
into a dense copy, then ``_sdpa`` runs over the whole ``max_len`` extent.
Per-token HBM traffic: read pool + write copy + read copy = 3x the cache
bytes, independent of how much of the table is actually filled.

This kernel streams K/V blocks *directly from the pool* through the block
table instead: the table (and each slot's fill) ride in as scalar-prefetch
operands, so the BlockSpec index map resolves ``table[b, j]`` to a physical
pool block per grid step — no gathered logical view exists anywhere.
Online-softmax state (running max m, denominator l, un-normalized
accumulator) is carried across the block grid in revisited output blocks,
exactly like the flash kernel (portable across interpret mode and TPU).

Two fill-awareness mechanisms compose:

* the index map **clamps** ``j`` to the last live block, so grid steps
  beyond the fill re-request the same block index — Pallas elides the
  copy when consecutive steps map to the same block, so dead table extent
  costs no HBM traffic;
* ``pl.when`` skips the compute for those steps entirely.

Masking modes (one kernel body serves both):

* ``causal=False`` — single-query decode: key position ``< lens[b]``
  (``lens`` = per-slot ``kv_len``).  Global caches pass ``pos+1``; the
  windowed ring passes ``min(pos+1, ring_len)`` — every resident ring
  slot is inside the window and softmax is order-invariant, so length
  masking is exact for both layouts.
* ``causal=True`` — multi-query suffix prefill: query rows are ``G`` head
  groups folded over ``q_len`` suffix positions (row ``r`` is suffix
  position ``r % q_len``), living at absolute position
  ``lens[b] + r % q_len`` (``lens`` = per-slot suffix start); keys are
  masked causally against that absolute position.

GQA is native: the grid runs over KV heads and each step computes all
``G = Hq/Hkv`` query rows against one K/V block — no repeated K/V.
Logit softcap (``tanh(s/c)*c``) is applied pre-mask, matching ``_sdpa``.

Kernels target TPU (VMEM blocks; pick ``bs``/``hd`` 128-aligned for MXU
shapes) and are validated on CPU with ``interpret=True`` against
``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(lens_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            scale, causal, q_len, bs, sk, softcap):
    """One (slot, kv-head, block) grid step of streaming-softmax attention.

    q_ref block ``[1, 1, R, hd]`` (R = G query rows, or G*q_len folded for
    prefill); k/v blocks ``[1, 1, bs, hd]``; o/m/l are revisited carry
    blocks.  ``sk`` is the static key extent — positions past it (a
    partial trailing block padded by Pallas) are masked *and* their V rows
    zeroed, because out-of-range block padding is undefined (NaN in
    interpret mode) and ``0 * NaN`` would poison the accumulator.

    ``ks_ref``/``vs_ref`` (static None when the pool is float) are
    per-KV-head scale vectors for int8 pools: each streamed block is
    dequantized *here*, fused into the grid step — no dense dequantized
    view of the cache ever exists.
    """
    b = pl.program_id(0)
    j = pl.program_id(2)
    # scale lookup stays OUTSIDE pl.when: program_id has no lowering rule
    # inside the nested cond jaxpr under interpret mode
    if ks_ref is not None:
        h = pl.program_id(1)
        ks, vs = ks_ref[h], vs_ref[h]

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref[...], NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    n = lens_ref[b]
    live = (j * bs <= n + q_len - 1) if causal else (j * bs < n)

    @pl.when(live)
    def _():
        q = q_ref[0, 0]  # [R, hd]
        k = k_ref[0, 0]  # [bs, hd]
        v = v_ref[0, 0]
        if ks_ref is not None:  # int8 pool: per-block fused dequantize
            k = k.astype(jnp.float32) * ks
            v = v.astype(jnp.float32) * vs
        if sk % bs:  # ragged trailing block possible (dense variant only)
            in_bounds = (j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)) < sk
            v = jnp.where(in_bounds, v, 0.0)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [R, bs]
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        r = q.shape[0]
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (r, bs), 1)
        if causal:
            q_pos = n + jax.lax.broadcasted_iota(jnp.int32, (r, bs), 0) % q_len
            mask = q_pos >= k_pos
        else:
            mask = k_pos < n
        if sk % bs:
            mask &= k_pos < sk
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0, 0]  # [R, 1]
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        # guard fully-masked rows (no valid keys yet)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_ref[0, 0] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        o_ref[0, 0] = alpha * o_ref[0, 0] + jax.lax.dot_general(
            p.astype(v.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[0, 0] = m_new


def _carry_specs(b, kvh, r, hd, index):
    return (
        [pl.BlockSpec((1, 1, r, hd), index),
         pl.BlockSpec((1, 1, r, 1), index),
         pl.BlockSpec((1, 1, r, 1), index)],
        [jax.ShapeDtypeStruct((b, kvh, r, hd), jnp.float32),
         jax.ShapeDtypeStruct((b, kvh, r, 1), jnp.float32),
         jax.ShapeDtypeStruct((b, kvh, r, 1), jnp.float32)],
    )


@functools.partial(jax.jit, static_argnames=("scale", "causal", "q_len",
                                             "softcap", "interpret"))
def paged_attention_kernel(
    q: jax.Array,       # [B, KVH, R, hd] grouped queries (R = G or G*q_len)
    k_pool: jax.Array,  # [n_blocks, KVH, bs, hd]
    v_pool: jax.Array,  # [n_blocks, KVH, bs, hd]
    table: jax.Array,   # [B, W] int32 logical->physical block ids
    lens: jax.Array,    # [B] int32: kv_len (decode) or suffix start (causal)
    k_scale: jax.Array = None,  # [KVH] f32 per-head scales (int8 pools)
    v_scale: jax.Array = None,  # [KVH] f32
    *,
    scale: float,
    causal: bool = False,
    q_len: int = 1,
    softcap: float = 0.0,
    interpret: bool = True,
):
    """Streamed paged attention.  Returns un-normalized (o, m, l).

    Int8 pools (``k_pool.dtype == int8``) require calibrated per-KV-head
    ``k_scale``/``v_scale`` vectors, ridden in as scalar-prefetch operands
    and applied per streamed block inside the kernel body.
    """
    b, kvh, r, hd = q.shape
    bs = k_pool.shape[2]
    w = table.shape[1]
    quantized = k_pool.dtype == jnp.int8
    if quantized and (k_scale is None or v_scale is None):
        raise ValueError("int8 KV pool needs calibrated k_scale/v_scale")
    kern = functools.partial(_kernel, scale=scale, causal=causal, q_len=q_len,
                             bs=bs, sk=w * bs, softcap=softcap)

    if quantized:
        def body(tbl_ref, lens_ref, ks_ref, vs_ref, *refs):
            return kern(lens_ref, ks_ref, vs_ref, *refs)
    else:
        def body(tbl_ref, lens_ref, *refs):
            return kern(lens_ref, None, None, *refs)

    def kv_index(bi, h, j, tbl, ln, *rest):
        # clamp to the last live block: dead extent re-requests the same
        # physical block, which Pallas does not re-copy (no HBM traffic),
        # and pl.when skips its compute
        last = ((ln[bi] + q_len - 1) if causal
                else jnp.maximum(ln[bi] - 1, 0)) // bs
        return (tbl[bi, jnp.minimum(j, last)], h, 0, 0)

    out_index = lambda bi, h, j, *rest: (bi, h, 0, 0)
    out_specs, out_shape = _carry_specs(b, kvh, r, hd, out_index)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4 if quantized else 2,
        grid=(b, kvh, w),
        in_specs=[
            pl.BlockSpec((1, 1, r, hd), lambda bi, h, j, *rest: (bi, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), kv_index),
            pl.BlockSpec((1, 1, bs, hd), kv_index),
        ],
        out_specs=out_specs,
    )
    operands = (table, lens) + (
        (jnp.asarray(k_scale, jnp.float32), jnp.asarray(v_scale, jnp.float32))
        if quantized else ())
    return pl.pallas_call(
        body, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(*operands, q, k_pool, v_pool)


@functools.partial(jax.jit, static_argnames=("scale", "bk", "softcap", "interpret"))
def dense_attention_kernel(
    q: jax.Array,      # [B, KVH, G, hd] grouped single-token queries
    k: jax.Array,      # [B, KVH, S, hd] dense per-slot cache
    v: jax.Array,      # [B, KVH, S, hd]
    kv_len: jax.Array,  # [B] int32 valid key count per slot
    *,
    scale: float,
    bk: int = 128,
    softcap: float = 0.0,
    interpret: bool = True,
):
    """Length-masked single-query decode over dense slot caches — the same
    streaming body, indexed contiguously (no table).  Returns (o, m, l).
    Beats full-extent ``_sdpa`` the same way the paged variant does: key
    blocks past ``kv_len`` are neither copied nor computed.
    """
    b, kvh, g, hd = q.shape
    sk = k.shape[2]
    w = -(-sk // bk)
    kern = functools.partial(_kernel, scale=scale, causal=False, q_len=1,
                             bs=bk, sk=sk, softcap=softcap)

    def body(lens_ref, *refs):
        return kern(lens_ref, None, None, *refs)

    def kv_index(bi, h, j, ln):
        return (bi, h, jnp.minimum(j, jnp.maximum(ln[bi] - 1, 0) // bk), 0)

    out_index = lambda bi, h, j, ln: (bi, h, 0, 0)
    out_specs, out_shape = _carry_specs(b, kvh, g, hd, out_index)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, w),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, h, j, ln: (bi, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), kv_index),
            pl.BlockSpec((1, 1, bk, hd), kv_index),
        ],
        out_specs=out_specs,
    )
    return pl.pallas_call(
        body, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(kv_len, q, k, v)
