"""Public paged-attention wrappers: GQA grouping, normalization, dtypes.

Three entry points, one streaming kernel body (``kernel.py``):

* :func:`paged_attention_decode`  — one token per slot against the pooled
  KV blocks (global causal and windowed-ring layouts: both reduce to
  length masking at decode time);
* :func:`paged_attention_prefill` — packed multi-token suffixes, causal
  against each slot's absolute ``start`` offset, past KV read straight
  from the pool (prefix-cache and chunked-prefill admission);
* :func:`dense_attention_decode`  — the dense per-slot cache layout,
  length-masked instead of full-``max_len``.

Queries arrive in the model's ``[B, H, ...]`` head layout; the wrappers
fold them into per-KV-head groups (no K/V repetition) and normalize the
kernel's un-normalized accumulator by the softmax denominator.  Inputs
are cast to the cache dtype (the engine keeps the two equal — KV dtype
follows model dtype); accumulation is fp32 inside the kernel and the
output is returned in the query dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    dense_attention_kernel, paged_attention_kernel,
)


def _normalize(o, l):
    return o / jnp.maximum(l, 1e-30)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_attention_decode(
    q: jax.Array,       # [B, H, hd] one query token per slot
    k_pool: jax.Array,  # [n_blocks, KVH, bs, hd]
    v_pool: jax.Array,  # [n_blocks, KVH, bs, hd]
    table: jax.Array,   # [B, W] int32
    kv_len: jax.Array,  # [B] int32 valid positions per slot (0 -> zeros out)
    k_scale: jax.Array = None,  # [KVH] f32: required for int8 pools
    v_scale: jax.Array = None,  # [KVH] f32
    *,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    b, h, hd = q.shape
    kvh = k_pool.shape[1]
    g = h // kvh
    # int8 pools: queries stay float (the kernel dequantizes K/V per block)
    qd = jnp.float32 if k_pool.dtype == jnp.int8 else k_pool.dtype
    qg = q.astype(qd).reshape(b, kvh, g, hd)
    o, _, l = paged_attention_kernel(
        qg, k_pool, v_pool, jnp.asarray(table, jnp.int32),
        jnp.asarray(kv_len, jnp.int32), k_scale, v_scale,
        scale=hd ** -0.5, causal=False,
        q_len=1, softcap=softcap, interpret=interpret,
    )
    return _normalize(o, l).reshape(b, h, hd).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_attention_prefill(
    q: jax.Array,       # [B, H, S, hd] packed suffix queries
    k_pool: jax.Array,  # [n_blocks, KVH, bs, hd] (suffix KV already written)
    v_pool: jax.Array,  # [n_blocks, KVH, bs, hd]
    table: jax.Array,   # [B, W_ctx] int32 (sliced to the context bucket)
    start: jax.Array,   # [B] int32 absolute position of each suffix row 0
    k_scale: jax.Array = None,  # [KVH] f32: required for int8 pools
    v_scale: jax.Array = None,  # [KVH] f32
    *,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    """Causal suffix attention with pooled past: query ``(b, i)`` sits at
    absolute position ``start[b] + i`` and sees every earlier pooled
    position (its prefix blocks plus its own freshly-written suffix KV).
    Padded suffix rows compute garbage that callers discard — the same
    contract as the gathered ``_sdpa`` path it replaces."""
    b, h, s, hd = q.shape
    kvh = k_pool.shape[1]
    g = h // kvh
    qd = jnp.float32 if k_pool.dtype == jnp.int8 else k_pool.dtype
    qg = q.astype(qd).reshape(b, kvh, g * s, hd)
    o, _, l = paged_attention_kernel(
        qg, k_pool, v_pool, jnp.asarray(table, jnp.int32),
        jnp.asarray(start, jnp.int32), k_scale, v_scale,
        scale=hd ** -0.5, causal=True,
        q_len=s, softcap=softcap, interpret=interpret,
    )
    return _normalize(o, l).reshape(b, h, s, hd).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "bk", "interpret"))
def dense_attention_decode(
    q: jax.Array,       # [B, H, hd]
    k: jax.Array,       # [B, KVH, S, hd] dense slot cache
    v: jax.Array,       # [B, KVH, S, hd]
    kv_len: jax.Array,  # [B] int32
    *,
    softcap: float = 0.0,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, hd = q.shape
    kvh = k.shape[1]
    g = h // kvh
    qg = q.astype(k.dtype).reshape(b, kvh, g, hd)
    o, _, l = dense_attention_kernel(
        qg, k, v, jnp.asarray(kv_len, jnp.int32), scale=hd ** -0.5,
        bk=min(bk, k.shape[2]), softcap=softcap, interpret=interpret,
    )
    return _normalize(o, l).reshape(b, h, hd).astype(q.dtype)
