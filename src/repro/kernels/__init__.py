"""Pallas TPU kernels for ASTRA's compute hot-spots.

Each subpackage ships ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jitted public wrapper) and ``ref.py`` (pure-jnp oracle):

* ``stoch_matmul``   — the OSSM array: packed-bitstream AND+popcount matmul
* ``bts_encode``     — B-to-S converter bank (int8 -> packed 128-bit streams)
* ``int8_matmul``    — ASTRA expectation fast path (MXU int8, output-stationary)
* ``flash_attention``— streaming-softmax attention (causal + sliding window)
* ``rglru_scan``     — chunked linear recurrence for RG-LRU/SSM blocks
* ``paged_attention``— gather-free serve-engine decode/suffix-prefill over
  the paged KV pool (block tables as scalar-prefetch operands)

Kernels target TPU (VMEM BlockSpecs, 128-aligned tiles) and are validated
on CPU with ``interpret=True``.
"""
