"""Jitted public wrapper: quantized matmul with output dequantization."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor
from repro.kernels.int8_matmul.kernel import int8_matmul_kernel


def _pad(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(xq: QTensor, wq: QTensor, *, bm=128, bn=128, bk=128, interpret=True) -> jax.Array:
    m, k = xq.q.shape
    n = wq.q.shape[1]
    x = _pad(_pad(xq.q, bm, 0), bk, 1)
    w = _pad(_pad(wq.q, bk, 0), bn, 1)
    acc = int8_matmul_kernel(x, w, bm=bm, bn=bn, bk=bk, interpret=interpret)[:m, :n]
    return acc.astype(jnp.float32) * xq.scale * wq.scale
