"""Pallas kernel: int8 x int8 -> int32 matmul — ASTRA's expectation fast path.

This is the TPU-native translation of ASTRA's insight (DESIGN.md §2): all
GEMMs — including dynamic-operand attention GEMMs — run in symmetric int8
with wide accumulation and a single output requantization ("one ADC at the
output").  Output-stationary: the int32 accumulator tile lives in VMEM and
is written once after the K loop.

Blocks default to 128x128x128: MXU-aligned (128 systolic dims), int8 tiles
of 16 KiB each and a 64 KiB fp32/int32 accumulator — comfortably in VMEM.
Grid = (M/bm, N/bn, K/bk), K innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_kernel(
    x: jax.Array,  # [M, K] int8
    w: jax.Array,  # [K, N] int8
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)
