"""Pure-jnp oracle for the int8 matmul kernel."""
import jax
import jax.numpy as jnp

from repro.core.quant import QTensor


def int8_matmul_acc_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """int8 [M,K] @ [K,N] -> int32 accumulator."""
    return jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def int8_matmul_ref(xq: QTensor, wq: QTensor) -> jax.Array:
    acc = int8_matmul_acc_ref(xq.q, wq.q)
    return acc.astype(jnp.float32) * xq.scale * wq.scale
