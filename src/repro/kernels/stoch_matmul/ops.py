"""Jitted public wrappers around the stochastic-matmul Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, STREAM_LEN
from repro.kernels.stoch_matmul.kernel import stoch_matmul_packed_kernel
from repro.kernels.stoch_matmul.ref import encode_operands


def _pad(a: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def stoch_matmul_packed(xs, sx, ws, sw, *, bm=32, bn=32, bk=32, interpret=True):
    """Packed-stream matmul with automatic block padding."""
    m, k = sx.shape
    n = sw.shape[0]
    xs, sx = _pad(_pad(xs, bm, 0), bk, 1), _pad(_pad(sx, bm, 0), bk, 1)
    ws, sw = _pad(_pad(ws, bn, 0), bk, 1), _pad(_pad(sw, bn, 0), bk, 1)
    # padded signs are 0 -> padded lanes contribute nothing
    out = stoch_matmul_packed_kernel(xs, sx, ws, sw, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("x_gen", "w_gen", "interpret"))
def stoch_matmul(
    xq: QTensor,
    wq: QTensor,
    x_gen: str = "thermometer",
    w_gen: str = "bresenham",
    interpret: bool = True,
) -> jax.Array:
    """Quantized [M,K] @ [K,N] through the OSSM-array kernel, dequantized."""
    xs, sx, ws, sw = encode_operands(xq.q, wq.q, x_gen, w_gen)
    acc = stoch_matmul_packed(xs, sx, ws, sw, interpret=interpret)
    return acc.astype(jnp.float32) * STREAM_LEN * xq.scale * wq.scale
