from repro.kernels.stoch_matmul.ops import stoch_matmul, stoch_matmul_packed
