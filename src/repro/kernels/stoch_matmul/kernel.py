"""Pallas kernel: the OSSM array — packed-bitstream stochastic matmul.

Computes out[m, n] = sum_k sign(x[m,k]*w[k,n]) * popcount(X[m,k] & W[k,n])
where X, W are 128-bit stochastic streams packed as 4 uint32 words.  The
AND is the optical AND gate; the popcount + signed add is the balanced
photo-charge accumulator; the k-sum is the analog in-situ accumulation of
one VDPE (pass tiling over K falls out of the bk block size).

TPU mapping: bit ops + popcount run on the VPU over int32 lanes; blocks are
chosen so the [bm, bn, bk] AND-popcount working set fits VMEM
(32x32x32 words x 4 B x 4 words = 2 MiB high-water).  The MXU is NOT used —
this kernel is the *fidelity* path; the deployable fast path is
``kernels/int8_matmul``.  Grid = (M/bm, N/bn, K/bk) with K innermost and
sequential ("arbitrary") for output accumulation.

Layout: streams are pre-transposed so both operands are K-contiguous:
  xs: [M, K, 4] uint32,  sx: [M, K] int8   (activation streams + signs)
  ws: [N, K, 4] uint32,  sw: [N, K] int8   (weight streams, transposed)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xs_ref, sx_ref, ws_ref, sw_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    xs = xs_ref[...]  # [bm, bk, 4] uint32
    ws = ws_ref[...]  # [bn, bk, 4] uint32
    # optical AND + photodetector popcount: [bm, bn, bk]
    pc = jnp.sum(
        jax.lax.population_count(xs[:, None, :, :] & ws[None, :, :, :]).astype(jnp.int32),
        axis=-1,
    )
    # balanced-PD sign steering
    s = (sx_ref[...].astype(jnp.int32)[:, None, :] * sw_ref[...].astype(jnp.int32)[None, :, :])
    # analog accumulation over this K tile (one VDPE pass group)
    o_ref[...] += jnp.sum(pc * s, axis=-1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def stoch_matmul_packed_kernel(
    xs: jax.Array,  # [M, K, 4] uint32
    sx: jax.Array,  # [M, K] int8 in {+1, -1}
    ws: jax.Array,  # [N, K, 4] uint32
    sw: jax.Array,  # [N, K] int8
    *,
    bm: int = 32,
    bn: int = 32,
    bk: int = 32,
    interpret: bool = True,
) -> jax.Array:
    m, k, w = xs.shape
    n = ws.shape[0]
    assert w == 4 and ws.shape == (n, k, 4), (xs.shape, ws.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk, 4), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk, 4), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(xs, sx, ws, sw)
