"""Pure-jnp oracle for the stochastic matmul kernel.

Built directly on the bit-exact OSSM functional model (core.ossm /
core.bitstream) — unpacks streams, ANDs, popcounts, signed-sums.  Slow and
memory-heavy by design; the kernel must match it bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitstream import unpack_bits
from repro.core.ossm import X_GEN, W_GEN
from repro.core.bitstream import encode_signed
from repro.core.quant import QTensor, STREAM_LEN


def stoch_matmul_packed_ref(xs, sx, ws, sw) -> jax.Array:
    """Same layout as the kernel: xs [M,K,4], ws [N,K,4] (K-contiguous)."""
    xb = unpack_bits(xs)  # [M, K, 128]
    wb = unpack_bits(ws)  # [N, K, 128]
    pc = jnp.einsum("mkb,nkb->mnk", xb, wb)  # AND == product of {0,1}
    s = sx.astype(jnp.int32)[:, None, :] * sw.astype(jnp.int32)[None, :, :]
    return jnp.sum(pc * s, axis=-1).astype(jnp.int32)


def encode_operands(xq: jax.Array, wq: jax.Array, x_gen: str = X_GEN, w_gen: str = W_GEN):
    """int8 [M,K] x [K,N] -> kernel layout (xs, sx, ws, sw)."""
    xs, sx = encode_signed(xq, x_gen)
    ws, sw = encode_signed(wq.T, w_gen)  # [N, K, 4]
    return xs, sx.astype(jnp.int8), ws, sw.astype(jnp.int8)


def stoch_matmul_ref(xq: QTensor, wq: QTensor, x_gen: str = X_GEN, w_gen: str = W_GEN) -> jax.Array:
    """End-to-end reference: quantized operands -> dequantized float output."""
    xs, sx, ws, sw = encode_operands(xq.q, wq.q, x_gen, w_gen)
    acc = stoch_matmul_packed_ref(xs, sx, ws, sw)
    return acc.astype(jnp.float32) * STREAM_LEN * xq.scale * wq.scale
