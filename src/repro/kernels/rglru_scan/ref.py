"""Pure-jnp oracle: sequential linear recurrence via lax.scan."""
import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0=None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a, b: [B, S, D]."""
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), a.dtype)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)
