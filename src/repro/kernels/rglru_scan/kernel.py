"""Pallas kernel: chunked linear recurrence  h_t = a_t * h_{t-1} + b_t.

The RG-LRU/SSM workhorse (RecurrentGemma).  Grid = (B/bb, S/chunk) with the
chunk dim sequential; the hidden state is carried across chunks in a
revisited carry output block (portable interpret/TPU pattern).  Inside a
chunk the recurrence runs as a log-depth associative scan over the chunk
axis — VPU-friendly, no per-step scalar loop.

VMEM: two [bb, chunk, D] blocks; with bb=8, chunk=256, D=512 fp32 that is
4 MiB high-water.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assoc(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, b1 * a2 + b2


def _kernel(a_ref, b_ref, o_ref, h_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]  # [bb, chunk, D]
    b = b_ref[...]
    # prefix scan within the chunk: h_t for h_{-1}=0
    aa, bb_ = jax.lax.associative_scan(_assoc, (a, b), axis=1)
    # fold in the carry: h_t = aa_t * h_in + bb_t
    h_in = h_ref[...][:, None, :]  # [bb, 1, D]
    h_all = aa * h_in + bb_
    o_ref[...] = h_all
    h_ref[...] = h_all[:, -1, :]


@functools.partial(jax.jit, static_argnames=("bb", "chunk", "interpret"))
def rglru_scan_kernel(
    a: jax.Array,  # [B, S, D] decay in (0, 1]
    b: jax.Array,  # [B, S, D] driven input
    *,
    bb: int = 8,
    chunk: int = 256,
    interpret: bool = True,
):
    bsz, s, d = a.shape
    assert bsz % bb == 0 and s % chunk == 0, (bsz, s, bb, chunk)
    o, _h = pl.pallas_call(
        _kernel,
        grid=(bsz // bb, s // chunk),
        in_specs=[
            pl.BlockSpec((bb, chunk, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((bb, chunk, d), lambda i, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, chunk, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((bb, d), lambda i, c: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), a.dtype),
            jax.ShapeDtypeStruct((bsz, d), a.dtype),
        ],
        interpret=interpret,
    )(a, b)
    return o
