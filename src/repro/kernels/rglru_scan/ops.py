"""Public wrapper with shape padding for the linear-recurrence kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import rglru_scan_kernel


@functools.partial(jax.jit, static_argnames=("bb", "chunk", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, *, bb: int = 8, chunk: int = 256, interpret: bool = True):
    bsz, s, d = a.shape
    bb = min(bb, bsz)
    chunk = min(chunk, s)
    pb, ps = (-bsz) % bb, (-s) % chunk
    if pb or ps:
        # pad decays with 1 and inputs with 0: padded steps keep state
        a = jnp.pad(a, ((0, pb), (0, ps), (0, 0)), constant_values=1)
        b = jnp.pad(b, ((0, pb), (0, ps), (0, 0)))
    out = rglru_scan_kernel(a, b, bb=bb, chunk=chunk, interpret=interpret)
    return out[:bsz, :s]
