from repro.kernels.bts_encode.ops import bts_encode
