"""Oracle: core.bitstream.encode_signed (the functional B-to-S model)."""
import jax.numpy as jnp

from repro.core.bitstream import encode_signed


def bts_encode_ref(q, generator="bresenham"):
    words, sign = encode_signed(q, generator)
    return words, sign.astype(jnp.int8)
