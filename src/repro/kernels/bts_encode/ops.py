"""Public wrapper for the B-to-S encoder kernel (pads to block multiples)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bts_encode.kernel import bts_encode_kernel


@functools.partial(jax.jit, static_argnames=("generator", "br", "bc", "interpret"))
def bts_encode(q: jax.Array, generator: str = "bresenham", br: int = 64, bc: int = 64, interpret: bool = True):
    r, c = q.shape
    br, bc = min(br, r), min(bc, c)
    pr, pc = (-r) % br, (-c) % bc
    if pr or pc:
        q = jnp.pad(q, ((0, pr), (0, pc)))
    words, sign = bts_encode_kernel(q, generator=generator, br=br, bc=bc, interpret=interpret)
    return words[:r, :c], sign[:r, :c]
