"""Pallas kernel: the B-to-S converter bank (binary -> stochastic streams).

Converts int8 sign-magnitude operands into packed 128-bit streams
(4 uint32 words) + sign lanes — the electronic front-end of every VDPE
(paper Fig. 3: "B-to-S circuits and serializers").  Pure VPU integer work;
each grid cell encodes a [rows, cols] tile into [rows, cols, 4] words.

Generators match ``core.bitstream``: thermometer (unary counter), bresenham
(clock-division with the round-to-nearest phase preset), and lfsr (the
7-bit maximal-LFSR comparator — realized as a constant visit-order table the
compiler folds into the kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitstream import LFSR_ORDER

STREAM_LEN = 128
N_WORDS = 4


def _encode_words(mag: jax.Array, order: jax.Array, generator: str) -> jax.Array:
    """mag [r, c] int32, order [128] visit table -> packed [r, c, 4] uint32."""
    r, c = mag.shape
    i = jax.lax.broadcasted_iota(jnp.int32, (r, c, N_WORDS, 32), 2) * 32 + jax.lax.broadcasted_iota(
        jnp.int32, (r, c, N_WORDS, 32), 3
    )
    m = mag[:, :, None, None]
    if generator == "thermometer":
        bits = (i < m).astype(jnp.uint32)
    elif generator == "bresenham":
        off = STREAM_LEN // 2
        bits = (((i + 1) * m + off) // STREAM_LEN - (i * m + off) // STREAM_LEN).astype(jnp.uint32)
    elif generator == "lfsr":
        bits = (order[i] < m).astype(jnp.uint32)
    else:
        raise ValueError(generator)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (r, c, N_WORDS, 32), 3)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def _kernel(q_ref, order_ref, words_ref, sign_ref, *, generator):
    q = q_ref[...].astype(jnp.int32)
    words_ref[...] = _encode_words(jnp.abs(q), order_ref[...], generator)
    sign_ref[...] = jnp.where(q < 0, -1, 1).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("generator", "br", "bc", "interpret"))
def bts_encode_kernel(q: jax.Array, *, generator="bresenham", br=64, bc=64, interpret=True):
    r, c = q.shape
    assert r % br == 0 and c % bc == 0
    kern = functools.partial(_kernel, generator=generator)
    # LFSR visit table rides along as a tiny replicated input (Pallas
    # kernels cannot capture constant arrays)
    order = jnp.asarray(LFSR_ORDER, jnp.int32)
    return pl.pallas_call(
        kern,
        grid=(r // br, c // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((STREAM_LEN,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, bc, N_WORDS), lambda i, j: (i, j, 0)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c, N_WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((r, c), jnp.int8),
        ],
        interpret=interpret,
    )(q, order)
