"""Public flash-attention wrapper: GQA folding, padding, head layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    scale = d ** -0.5
    # GQA: fold the G query heads sharing each KV head over the query axis
    # (rows [g*Sq + i] of pair (b, kvh)) — K/V are never repeated; the
    # kernel recovers true positions via the q_len fold period
    g = hq // hkv
    qf = q.reshape(b * hkv, g * sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)
    # pad sequence dims to block multiples; padded keys are masked by causal
    # + explicit key-validity (padded queries discarded on slice-out)
    pq, pk = (-g * sq) % bq, (-sk) % bk
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
    eff_window = window
    if not causal and pk:
        # non-causal path must not attend to padded keys; emulate with a
        # window covering exactly the valid span (encoder use is full-span)
        raise NotImplementedError("non-causal padding unsupported; pad inputs to block size")
    o, _, _ = flash_attention_kernel(
        qf, kf, vf, scale=scale, causal=causal, window=eff_window, bq=bq, bk=bk,
        q_len=sq, softcap=softcap, interpret=interpret,
    )
    return o[:, : g * sq].reshape(b, hkv, g, sq, d).reshape(b, hq, sq, d).astype(q.dtype)
