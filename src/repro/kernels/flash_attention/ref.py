"""Pure-jnp oracle: masked softmax attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, scale, causal=True, window=0):
    """q [BH, Sq, D], k/v [BH, Sk, D] -> [BH, Sq, D] (fp32).

    When Sq < Sk (decode/chunked prefill) queries are right-aligned:
    query i sits at absolute position Sk - Sq + i.
    """
    q, k, v = (x.astype(jnp.float32) for x in (q, k, v))
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    sq, sk = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None], p, 0.0)
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p / denom, v)
