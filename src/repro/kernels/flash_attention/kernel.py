"""Pallas kernel: streaming-softmax (flash) attention, causal + sliding window.

Serves the prefill path of every attention arch (global and local blocks
share this kernel — ``window=0`` means unbounded causal context).  GQA is
handled by the wrapper (queries grouped per KV head): the ``G`` query
heads sharing a KV head are stacked over the query axis and ``q_len``
tells the kernel the fold period, so each K/V tile is read once per
*group* rather than once per query head.  Logit softcap (``tanh(s/c)*c``,
pre-mask) matches the ``_sdpa`` ordering.

Blocking: grid = (BH, Sq/bq, Sk/bk) with the K dim innermost & sequential.
Online softmax state (running max m, denominator l) and the un-normalized
accumulator are carried across K steps in *output* blocks that are
revisited (portable across interpret mode and TPU; avoids TPU-only scratch
shapes).  The wrapper normalizes and strips the side outputs.

VMEM: bq x d + bk x d tiles + bq x bk score block; 128x128 fp32 blocks +
d<=256 keep the working set ~0.5 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, scale, causal, window,
            bq, bk, q_len, softcap):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref[...], NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # [bq, d]
    k = k_ref[0]  # [bk, d]
    v = v_ref[0]  # [bk, d]
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    iq = pl.program_id(1)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], k.shape[0]), 0)
    if q_len:
        # GQA fold: query rows are G head groups stacked over q_len real
        # positions — row r of the folded axis sits at position r % q_len
        q_pos = q_pos % q_len
    k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], k.shape[0]), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]  # [bq, 1]
    l_prev = l_ref[0]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (no valid keys yet)
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    o_new = alpha * o_ref[0] + jax.lax.dot_general(
        p.astype(v.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = o_new


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window", "bq",
                                             "bk", "q_len", "softcap", "interpret"))
def flash_attention_kernel(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,  # [BH, Sk, D]
    v: jax.Array,  # [BH, Sk, D]
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    q_len: int = 0,  # GQA fold period: row r is query position r % q_len (0 = identity)
    softcap: float = 0.0,
    interpret: bool = True,
):
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    kern = functools.partial(_kernel, scale=scale, causal=causal, window=window,
                             bq=bq, bk=bk, q_len=q_len, softcap=softcap)
    o, m, l = pl.pallas_call(
        kern,
        grid=(bh, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o / jnp.maximum(l, 1e-30), m, l
