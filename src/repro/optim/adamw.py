"""AdamW with fp32 master state, global-norm clipping, decoupled decay.

Self-contained (no optax in this environment).  State pytrees mirror the
parameter tree, so the sharding rules for params apply verbatim to m/v —
ZeRO-style optimizer-state sharding falls out of the FSDP param specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    params, grads, state, cfg: AdamWConfig, lr_scale: jax.Array | float = 1.0
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        p2 = p.astype(jnp.float32) - lr * (update + cfg.weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
