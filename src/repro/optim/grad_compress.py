"""Error-feedback int8 gradient compression for the pod-crossing all-reduce.

At multi-pod scale the DP all-reduce over the ``pod`` axis rides the slow
inter-pod links (DCN), so we compress: per-leaf symmetric int8 quantization
with an error-feedback residual (Seide et al. / EF-SGD) so compression bias
vanishes over steps.  Used inside a shard_map over the pod axis; within-pod
reduction stays full precision.

``compressed_psum(g, axis, state)``: quantize(g + residual) -> int8 psum ->
dequantize; new residual = (g + residual) - dequantized_local.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressorState(NamedTuple):
    residual: Any  # pytree matching grads


def compress_init(grads) -> CompressorState:
    return CompressorState(jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads))


def compressed_psum(grads, axis_name: str, state: CompressorState) -> Tuple[Any, CompressorState]:
    """int8-compressed psum over ``axis_name`` with error feedback."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        # shared quantization scale: pmax of the local absmax (a scalar
        # collective, negligible next to the int8 payload) — every member
        # quantizes AND dequantizes on the same grid, so the int8 psum is
        # exact up to per-member rounding.
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        n = jax.lax.psum(1, axis_name)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        deq = q_sum.astype(jnp.float32) * scale / n
        local_deq = q.astype(jnp.float32) * scale
        new_r = gf - local_deq
        return deq.astype(g.dtype), new_r

    out = jax.tree.map(one, grads, state.residual)
    g2 = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    r2 = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g2, CompressorState(r2)
