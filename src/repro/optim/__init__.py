from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.optim.grad_compress import CompressorState, compress_init, compressed_psum

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
    "CompressorState", "compress_init", "compressed_psum",
]
