from repro.data.pipeline import DataConfig, SyntheticLMDataset, Prefetcher, make_batch_iterator

__all__ = ["DataConfig", "SyntheticLMDataset", "Prefetcher", "make_batch_iterator"]
