"""Deterministic synthetic LM data pipeline with straggler mitigation.

Design constraints from the fault-tolerance story:

* **Step-addressable determinism** — ``batch_at(step)`` is a pure function of
  ``(seed, step)``, so a restarted (or re-scaled) job resumes with *exactly*
  the batch sequence it would have seen, no data-loader state to checkpoint.
* **Learnable structure** — tokens follow a seeded order-1 Markov chain with
  a skewed transition table plus periodic copy spans, so tiny models show a
  clearly decreasing loss in the e2e tests/examples (uniform-random tokens
  would pin the loss at log(V)).
* **Straggler mitigation** — :class:`Prefetcher` produces batches on a
  background thread with a bounded queue; if the producer misses the
  ``timeout_s`` deadline (a simulated straggling input shard), the consumer
  substitutes the deterministic *backup batch* for that step and keeps the
  step time bounded.  Substitutions are counted and reported.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

import jax


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_codebooks: int = 0  # audio grids: tokens [B, C, S]
    vision_tokens: int = 0  # vlm: attach stub patch embeddings
    d_model: int = 0  # embedding dim for vision stub
    copy_period: int = 64  # every k-th position starts a copy span
    copy_len: int = 8
    menu_size: int = 8  # successors per state (smaller => more learnable)
    greedy_p: float = 0.9  # probability of taking a menu successor


class SyntheticLMDataset:
    """Order-1 Markov token stream, step-addressable."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # skewed per-state successor menu: each state transitions to one of
        # ``menu_size`` preferred successors with p=greedy_p, else uniform.
        # Small menu => low conditional entropy => learnable by tiny models
        # in a few steps.
        self._menu = rng.integers(0, v, size=(min(v, 4096), cfg.menu_size), dtype=np.int64)

    def batch_at(self, step: int) -> Dict[str, Any]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b = cfg.global_batch
        rows = b * max(cfg.n_codebooks, 1)
        s = cfg.seq_len
        v = cfg.vocab
        n_states = self._menu.shape[0]
        toks = np.empty((rows, s), dtype=np.int64)
        toks[:, 0] = rng.integers(0, v, size=rows)
        greedy = rng.random((rows, s)) < cfg.greedy_p
        choice = rng.integers(0, cfg.menu_size, size=(rows, s))
        uniform = rng.integers(0, v, size=(rows, s))
        for t in range(1, s):
            prev = toks[:, t - 1] % n_states
            toks[:, t] = np.where(greedy[:, t], self._menu[prev, choice[:, t]], uniform[:, t])
        # copy spans: repeat the previous ``copy_len`` tokens verbatim
        if cfg.copy_period and s > 2 * cfg.copy_len:
            for start in range(cfg.copy_period, s - cfg.copy_len, cfg.copy_period):
                toks[:, start : start + cfg.copy_len] = toks[:, start - cfg.copy_len : start]
        toks = toks.astype(np.int32)
        if cfg.n_codebooks:
            toks = toks.reshape(b, cfg.n_codebooks, s)
        batch: Dict[str, Any] = {"tokens": toks}
        if cfg.vision_tokens:
            emb = rng.standard_normal((b, cfg.vision_tokens, cfg.d_model)).astype(np.float32)
            batch["vision_embeds"] = emb
        return batch


class Prefetcher:
    """Bounded background prefetch with a straggler deadline.

    ``get(step)`` returns the batch for ``step``; if the producer thread has
    not delivered it within ``timeout_s`` the deterministic backup batch
    (computed synchronously) is substituted — the training loop never stalls
    on one slow input shard.
    """

    def __init__(self, dataset: SyntheticLMDataset, depth: int = 2,
                 timeout_s: float = 30.0, delay_injector=None):
        self.dataset = dataset
        self.timeout_s = timeout_s
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next_step = 0
        self._delay = delay_injector  # callable(step) -> seconds, for tests
        self.substituted_steps: list = []
        self._thread: Optional[threading.Thread] = None

    def start(self, first_step: int = 0):
        self._next_step = first_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def _run(self):
        step = self._next_step
        while not self._stop.is_set():
            if self._delay is not None:
                d = self._delay(step)
                if d:
                    self._stop.wait(d)
            batch = self.dataset.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self, step: int) -> Dict[str, Any]:
        try:
            got_step, batch = self._q.get(timeout=self.timeout_s)
            if got_step == step:
                return batch
            # mismatch (e.g. a restart rewound the step counter): determinism
            # beats pipelining — recompute synchronously.
            return self.dataset.batch_at(step)
        except queue.Empty:
            self.substituted_steps.append(step)
            return self.dataset.batch_at(step)  # deterministic backup

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)


def make_batch_iterator(
    cfg: DataConfig, sharding=None, first_step: int = 0
) -> Iterator[Dict[str, Any]]:
    """Simple synchronous iterator; ``sharding`` device_puts each batch."""
    ds = SyntheticLMDataset(cfg)
    step = first_step
    while True:
        batch = ds.batch_at(step)
        if sharding is not None:
            batch = jax.tree.map(
                lambda a, s=sharding: jax.device_put(a, s) if hasattr(a, "shape") else a, batch
            )
        yield batch
        step += 1
