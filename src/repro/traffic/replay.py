"""Trace replay: drive a ``ServeFrontend`` with a traffic trace.

The replay loop is the only place in the traffic stack where time
*passes*; everything upstream (arrivals, scenarios, trace generation) is
pure.  Two clock modes:

* **virtual** — the engine, front-end, and replay all share one
  :class:`VirtualClock`.  Each front-end pump advances the clock by a
  fixed ``step_s`` (a stand-in for the engine round's service time), and
  idle gaps jump straight to the next arrival.  The entire latency
  trajectory — queue waits, TTFT, ITL, timeout rejections — becomes a
  deterministic function of ``(trace, engine config, step_s)``: two
  replays of the same trace are bit-identical.  This is the mode the
  determinism claim in ``BENCH_traffic.json`` is checked under.
* **wall** — no virtual clock; the replay paces arrivals with
  ``time.sleep`` against the real clock and the engine stamps real
  timestamps.  Latencies are honest but machine-dependent; token
  streams are still deterministic (greedy sampling).

Either way the replay captures every request's incremental token stream
through the front-end's ``on_tokens`` path, so callers can check the
streamed tokens against the terminal ``RequestOutput``s.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serve.engine import RequestOutput
from repro.serve.frontend import ServeFrontend
from repro.traffic.trace import TrafficTrace


class VirtualClock:
    """A manually advanced clock, callable like ``time.time``.

    Pass one instance as ``ServeEngine(clock=...)`` (the front-end
    inherits it) and to :func:`replay_trace`; the replay advances it,
    and every timing the stack records becomes deterministic.
    """

    def __init__(self, start_s: float = 0.0):
        self._t = float(start_s)

    def __call__(self) -> float:
        return self._t

    def now(self) -> float:
        return self._t

    def advance(self, dt_s: float) -> None:
        if dt_s < 0:
            raise ValueError(f"cannot advance clock by dt_s={dt_s} < 0")
        self._t += dt_s


@dataclasses.dataclass
class ReplayResult:
    """Everything one replayed trace produced.

    ``outputs`` are the terminal :class:`RequestOutput`s (completions
    *and* rejections) in finish order; ``request_ids[i]`` is the engine
    request id assigned to ``trace.requests[i]``; ``token_streams``
    maps request id -> the concatenation of every streamed chunk (equal
    to the terminal ``tokens`` for completed requests, empty for
    rejected ones); ``stats`` is the front-end counter snapshot
    (submitted/completed/rejected/queue high-water).
    """

    outputs: List[RequestOutput]
    request_ids: List[int]
    token_streams: Dict[int, np.ndarray]
    duration_s: float
    stats: Dict

    @property
    def outputs_by_id(self) -> Dict[int, RequestOutput]:
        return {o.request_id: o for o in self.outputs}


def replay_trace(frontend: ServeFrontend, trace: TrafficTrace,
                 virtual_step_s: Optional[float] = None) -> ReplayResult:
    """Feed ``trace`` through ``frontend`` on its arrival schedule.

    ``virtual_step_s`` selects the clock mode: a positive float runs in
    virtual time (the front-end's clock must be a :class:`VirtualClock`;
    each pump advances it by ``virtual_step_s``), ``None`` runs in wall
    time (arrival gaps are slept for real).
    """
    clock = frontend.clock
    if virtual_step_s is not None:
        if virtual_step_s <= 0:
            raise ValueError(
                f"virtual_step_s={virtual_step_s} must be > 0 (or None "
                "for wall-clock replay)")
        if not isinstance(clock, VirtualClock):
            raise ValueError(
                "virtual replay needs the front-end (and engine) built on "
                "a VirtualClock; pass clock=VirtualClock() to ServeEngine")

    chunks: Dict[int, List[np.ndarray]] = {}

    def _sink_for(rid_box: List[int]):
        def _sink(toks: np.ndarray) -> None:
            chunks.setdefault(rid_box[0], []).append(np.asarray(toks))
        return _sink

    # when the front-end retries a faulted attempt, its partial stream is
    # withdrawn — drop our copy too so `token_streams` stays equal to the
    # terminal output for retried-then-completed requests
    prev_on_retry = frontend.on_retry

    def _on_retry(rid: int) -> None:
        chunks.pop(rid, None)
        if prev_on_retry is not None:
            prev_on_retry(rid)

    frontend.on_retry = _on_retry

    t0 = clock()
    reqs = trace.requests
    rids: List[int] = []
    i = 0
    while i < len(reqs) or frontend.busy():
        now = clock() - t0
        while i < len(reqs) and reqs[i].arrival_s <= now + 1e-12:
            box: List[int] = [-1]
            sink = _sink_for(box)
            rid = frontend.submit(reqs[i].prompt, reqs[i].max_new_tokens,
                                  on_tokens=sink)
            box[0] = rid
            rids.append(rid)
            i += 1
        if frontend.busy():
            # each engine round costs step_s of virtual time; advancing
            # *before* the pump puts the round's timestamps (admission,
            # first token, chunk arrivals) at round end, so TTFT/ITL are
            # nonzero multiples of the round time
            if virtual_step_s is not None:
                clock.advance(virtual_step_s)
            frontend.pump()
        elif i < len(reqs):
            gap = (t0 + reqs[i].arrival_s) - clock()
            if virtual_step_s is not None:
                clock.advance(max(gap, 0.0))
            elif gap > 0:
                time.sleep(gap)
    outputs = frontend.drain()
    duration = clock() - t0

    streams: Dict[int, np.ndarray] = {}
    for idx, rid in enumerate(rids):
        parts = chunks.get(rid, [])
        if parts:
            streams[rid] = np.concatenate(parts, axis=-1)
        else:
            p = np.asarray(reqs[idx].prompt)
            shape = p.shape[:-1] + (0,)
            streams[rid] = np.zeros(shape, np.int32)
    return ReplayResult(outputs=outputs, request_ids=rids,
                        token_streams=streams, duration_s=duration,
                        stats=dict(frontend.stats))
