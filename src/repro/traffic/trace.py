"""Traffic traces: a fully materialized, replayable request schedule.

A :class:`TrafficTrace` is the load generator's output — every request's
arrival offset, prompt tokens, generation budget, and scenario label,
fixed before any serving happens.  Generation is a pure function of
``(suite, rate, n, seed, arrival process)``: one seeded
``numpy.random.Generator`` drives both the arrival gaps and the request
sampling, so two calls with the same arguments produce bit-identical
traces (and the replay of a trace never consults the generator again).

Traces round-trip through JSON (``save``/``load``) so a trace can be
pinned as a CLI artifact (``launch/serve.py --traffic-trace trace.json``)
or regenerated on the fly from a spec string like ``"chat:rate=2,n=64"``
(:func:`parse_trace_spec`).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List

import numpy as np

from repro.traffic.arrivals import (
    ARRIVAL_PROCESSES, bursty_arrivals, poisson_arrivals,
)
from repro.traffic.scenarios import SUITES, sample_requests, suite_max_total_len


@dataclasses.dataclass(frozen=True)
class TracedRequest:
    arrival_s: float  # offset from trace start (virtual or wall — replay decides)
    prompt: np.ndarray  # [S] or [C, S] int32
    max_new_tokens: int
    scenario: str

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[-1])


@dataclasses.dataclass(frozen=True)
class TrafficTrace:
    suite: str
    rate_rps: float  # offered load the arrivals were drawn at
    seed: int
    arrival: str  # "poisson" | "bursty"
    requests: List[TracedRequest]

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def duration_s(self) -> float:
        """Span of the arrival schedule (last arrival offset)."""
        return self.requests[-1].arrival_s if self.requests else 0.0

    @property
    def max_total_len(self) -> int:
        return max((r.prompt_len + r.max_new_tokens for r in self.requests),
                   default=0)

    # ------------------------------------------------------------- JSON
    def to_dict(self) -> Dict:
        return {
            "suite": self.suite, "rate_rps": self.rate_rps, "seed": self.seed,
            "arrival": self.arrival,
            "requests": [
                {"arrival_s": r.arrival_s, "prompt": np.asarray(r.prompt).tolist(),
                 "max_new_tokens": r.max_new_tokens, "scenario": r.scenario}
                for r in self.requests
            ],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "TrafficTrace":
        reqs = [TracedRequest(float(r["arrival_s"]),
                              np.asarray(r["prompt"], np.int32),
                              int(r["max_new_tokens"]), str(r["scenario"]))
                for r in d["requests"]]
        return cls(str(d["suite"]), float(d["rate_rps"]), int(d["seed"]),
                   str(d.get("arrival", "poisson")), reqs)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)

    @classmethod
    def load(cls, path: str) -> "TrafficTrace":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def generate_trace(suite: str, rate_rps: float, n: int, seed: int, vocab: int,
                   arrival: str = "poisson", n_codebooks: int = 0,
                   burst_size: int = 8) -> TrafficTrace:
    """Build a deterministic trace: ``n`` requests from ``SUITES[suite]``
    arriving at offered load ``rate_rps``.

    One generator seeded with ``seed`` drives arrivals *then* request
    sampling, so the trace is a pure function of the arguments.
    """
    if suite not in SUITES:
        raise ValueError(f"unknown suite {suite!r}; available: "
                         f"{', '.join(sorted(SUITES))}")
    if arrival not in ARRIVAL_PROCESSES:
        raise ValueError(f"unknown arrival process {arrival!r}; available: "
                         f"{', '.join(ARRIVAL_PROCESSES)}")
    rng = np.random.default_rng(seed)
    if arrival == "poisson":
        times = poisson_arrivals(rate_rps, n, rng)
    else:
        times = bursty_arrivals(rate_rps, n, rng, burst_size=burst_size)
    reqs = sample_requests(SUITES[suite], n, vocab, rng, n_codebooks)
    return TrafficTrace(suite, rate_rps, seed, arrival, [
        TracedRequest(float(t), p, g, name)
        for t, (name, p, g) in zip(times, reqs)
    ])


def parse_trace_spec(spec: str) -> Dict:
    """Parse a ``suite[:key=value,...]`` CLI spec into generate_trace kwargs.

    Example: ``"chat:rate=2.0,n=64,seed=1,arrival=bursty"``.  Returns a
    dict with ``suite``/``rate_rps``/``n``/``seed``/``arrival`` keys
    (missing keys defaulted); raises ``ValueError`` on unknown suites,
    keys, or processes so the CLI can report the offending value.
    """
    head, _, tail = spec.partition(":")
    if head not in SUITES:
        raise ValueError(f"unknown suite {head!r}; available: "
                         f"{', '.join(sorted(SUITES))}")
    out: Dict = {"suite": head, "rate_rps": 1.0, "n": 32, "seed": 0,
                 "arrival": "poisson"}
    if tail:
        for item in tail.split(","):
            k, sep, v = item.partition("=")
            if not sep:
                raise ValueError(f"bad trace spec item {item!r} "
                                 "(expected key=value)")
            if k == "rate":
                out["rate_rps"] = float(v)
            elif k == "n":
                out["n"] = int(v)
            elif k == "seed":
                out["seed"] = int(v)
            elif k == "arrival":
                if v not in ARRIVAL_PROCESSES:
                    raise ValueError(f"unknown arrival process {v!r}; "
                                     f"available: {', '.join(ARRIVAL_PROCESSES)}")
                out["arrival"] = v
            else:
                raise ValueError(f"unknown trace spec key {k!r} "
                                 "(known: rate, n, seed, arrival)")
    if out["rate_rps"] <= 0:
        raise ValueError(f"trace spec rate={out['rate_rps']} must be > 0")
    if out["n"] < 1:
        raise ValueError(f"trace spec n={out['n']} must be >= 1")
    return out


def trace_max_len(trace: TrafficTrace, headroom: int = 1) -> int:
    """Engine ``max_len`` floor for a trace (worst prompt+gen, plus slack)."""
    return trace.max_total_len + headroom


def suite_engine_max_len(suite: str, headroom: int = 1) -> int:
    """Engine ``max_len`` floor covering *any* trace from the suite."""
    return suite_max_total_len(SUITES[suite]) + headroom
