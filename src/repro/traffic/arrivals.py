"""Deterministic arrival processes for the open-loop load generator.

Every function here is a pure map ``(parameters, rng) -> arrival times``:
times are offsets in seconds from the start of the trace, produced by a
caller-owned ``numpy.random.Generator`` — **no wall clock anywhere**.
Two calls with equally seeded generators produce bit-identical traces
(the acceptance bar for ``BENCH_traffic.json``); what "a second" means
is decided later, by the replay clock (``repro.traffic.replay``).

* :func:`poisson_arrivals` — the classic open-loop model: exponential
  i.i.d. inter-arrival gaps at ``rate_rps`` requests/second.  Memoryless,
  so instantaneous load fluctuates around the offered rate.
* :func:`bursty_arrivals` — an on/off burst process: burst *epochs*
  arrive Poisson at ``rate_rps / burst_size``, and each epoch releases
  ``burst_size`` requests over a short intra-burst spread.  Same average
  offered load as the Poisson trace, far worse peak-to-mean ratio — the
  trace that exercises bounded-queue backpressure and queue-timeout
  rejection (docs/SERVING.md §Traffic).
"""
from __future__ import annotations

import numpy as np


def poisson_arrivals(rate_rps: float, n: int,
                     rng: np.random.Generator) -> np.ndarray:
    """``n`` Poisson arrival times (seconds from trace start), float64."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps={rate_rps} must be > 0")
    if n < 0:
        raise ValueError(f"n={n} must be >= 0")
    return np.cumsum(rng.exponential(1.0 / rate_rps, n))


def bursty_arrivals(rate_rps: float, n: int, rng: np.random.Generator,
                    burst_size: int = 8,
                    burst_spread_s: float = 0.0) -> np.ndarray:
    """``n`` bursty arrival times with the same mean rate as Poisson.

    Burst epochs are Poisson at ``rate_rps / burst_size``; each epoch
    contributes ``burst_size`` arrivals (the last burst is truncated to
    reach exactly ``n``) spaced uniformly within ``burst_spread_s``
    seconds of the epoch.  ``burst_spread_s=0`` packs each burst into a
    single instant — the hardest case for the admission queue.
    """
    if burst_size < 1:
        raise ValueError(f"burst_size={burst_size} must be >= 1")
    if burst_spread_s < 0:
        raise ValueError(f"burst_spread_s={burst_spread_s} must be >= 0")
    n_bursts = -(-n // burst_size)
    epochs = poisson_arrivals(rate_rps / burst_size, n_bursts, rng)
    times = []
    for e in epochs:
        k = min(burst_size, n - len(times))
        offs = (rng.uniform(0.0, burst_spread_s, k) if burst_spread_s > 0
                else np.zeros(k))
        times.extend(e + np.sort(offs))
    return np.asarray(times[:n])


ARRIVAL_PROCESSES = ("poisson", "bursty")
