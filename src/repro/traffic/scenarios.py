"""Scenario suites: what the requests in a traffic trace look like.

A :class:`Scenario` is a seeded distribution over (prompt, gen length)
pairs; a **suite** is a weighted mixture of scenarios.  The three shipped
suites mirror the serving workloads the engine's machinery was built for
(ROADMAP continuous-traffic item):

* ``chat`` — short prompts, mid-length generations; the latency-critical
  interactive mix.
* ``longdoc`` — long prompts, short generations (summarization): the
  prefill-heavy workload the chunked-prefill scheduler exists for.
* ``agent`` — shared-prefix fan-out: many requests extend one of a few
  long common prefixes (a system prompt / tool preamble), the workload
  the radix-tree prefix cache turns from O(prompt) into O(suffix).
* ``mixed`` — all three, weighted toward chat.

Prompts are drawn from a caller-owned ``numpy.random.Generator`` — fully
deterministic under a fixed seed, no wall clock.  Shared prefixes are
derived from a scenario-local generator seeded by ``prefix_seed`` so the
*same* prefix pool is regenerated for every trace built from the suite
(prefix-cache hits survive across traces with different arrival seeds).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One request population inside a suite.

    ``prompt_lens`` / ``gen_lens`` are the discrete choice sets sampled
    uniformly per request.  ``shared_prefix_len > 0`` makes every request
    start with one of ``n_prefixes`` fixed token prefixes (chosen
    uniformly), regenerated deterministically from ``prefix_seed``.
    """

    name: str
    prompt_lens: Tuple[int, ...]
    gen_lens: Tuple[int, ...]
    weight: float = 1.0
    shared_prefix_len: int = 0
    n_prefixes: int = 1
    prefix_seed: int = 0x5EED

    def __post_init__(self):
        if not self.prompt_lens or min(self.prompt_lens) < 1:
            raise ValueError(f"{self.name}: prompt_lens {self.prompt_lens} "
                             "must be non-empty and >= 1")
        if not self.gen_lens or min(self.gen_lens) < 0:
            raise ValueError(f"{self.name}: gen_lens {self.gen_lens} "
                             "must be non-empty and >= 0")
        if self.shared_prefix_len >= min(self.prompt_lens):
            if self.shared_prefix_len > 0:
                raise ValueError(
                    f"{self.name}: shared_prefix_len {self.shared_prefix_len} "
                    f"must leave at least one suffix token below the shortest "
                    f"prompt ({min(self.prompt_lens)})"
                )
        if self.weight <= 0:
            raise ValueError(f"{self.name}: weight {self.weight} must be > 0")

    @property
    def max_total_len(self) -> int:
        return max(self.prompt_lens) + max(self.gen_lens)


SUITES: Dict[str, Tuple[Scenario, ...]] = {
    "chat": (
        Scenario("chat", prompt_lens=(8, 12, 16, 24), gen_lens=(12, 16, 24)),
    ),
    "longdoc": (
        Scenario("summarize", prompt_lens=(96, 128, 160), gen_lens=(6, 10)),
    ),
    "agent": (
        Scenario("fanout", prompt_lens=(48, 56, 64), gen_lens=(8, 12),
                 shared_prefix_len=32, n_prefixes=2),
    ),
    "mixed": (
        Scenario("chat", prompt_lens=(8, 12, 16, 24), gen_lens=(12, 16, 24),
                 weight=3.0),
        Scenario("summarize", prompt_lens=(96, 128, 160), gen_lens=(6, 10),
                 weight=1.0),
        Scenario("fanout", prompt_lens=(48, 56, 64), gen_lens=(8, 12),
                 weight=2.0, shared_prefix_len=32, n_prefixes=2),
    ),
}


def suite_max_total_len(suite: Tuple[Scenario, ...]) -> int:
    """Worst-case ``prompt + gen`` over the suite — the floor for the
    engine's ``max_len``."""
    return max(s.max_total_len for s in suite)


def _prefix_pool(scenario: Scenario, vocab: int,
                 n_codebooks: int) -> List[np.ndarray]:
    """The scenario's fixed shared prefixes, regenerated from its seed."""
    rng = np.random.default_rng(scenario.prefix_seed)
    shape = ((n_codebooks, scenario.shared_prefix_len) if n_codebooks
             else (scenario.shared_prefix_len,))
    return [rng.integers(0, vocab, shape, dtype=np.int32)
            for _ in range(scenario.n_prefixes)]


def sample_requests(suite: Tuple[Scenario, ...], n: int, vocab: int,
                    rng: np.random.Generator,
                    n_codebooks: int = 0) -> List[Tuple[str, np.ndarray, int]]:
    """Draw ``n`` requests from the suite mixture.

    Returns ``[(scenario_name, prompt, max_new_tokens)]`` in draw order —
    deterministic given the generator's state.
    """
    weights = np.asarray([s.weight for s in suite], np.float64)
    weights = weights / weights.sum()
    pools = {s.name: _prefix_pool(s, vocab, n_codebooks)
             for s in suite if s.shared_prefix_len > 0}
    out: List[Tuple[str, np.ndarray, int]] = []
    for _ in range(n):
        s = suite[int(rng.choice(len(suite), p=weights))]
        p_len = int(rng.choice(np.asarray(s.prompt_lens)))
        g_len = int(rng.choice(np.asarray(s.gen_lens)))
        tail_len = p_len - s.shared_prefix_len
        shape = (n_codebooks, tail_len) if n_codebooks else (tail_len,)
        tail = rng.integers(0, vocab, shape, dtype=np.int32)
        if s.shared_prefix_len > 0:
            prefix = pools[s.name][int(rng.choice(s.n_prefixes))]
            prompt = np.concatenate([prefix, tail], axis=-1)
        else:
            prompt = tail
        out.append((s.name, prompt, g_len))
    return out
