"""Deterministic open-loop traffic generation, replay, and SLO scoring.

The package splits cleanly into a *pure* half and a *time-passing* half:

* pure — :mod:`repro.traffic.arrivals` (Poisson / bursty arrival
  processes), :mod:`repro.traffic.scenarios` (chat / longdoc / agent
  fan-out suites), :mod:`repro.traffic.trace` (materialized replayable
  traces; JSON round-trip; CLI spec parsing).  No wall clock anywhere:
  a trace is a pure function of ``(suite, rate, n, seed)``.
* time-passing — :mod:`repro.traffic.replay` drives a
  :class:`~repro.serve.frontend.ServeFrontend` with a trace on either a
  :class:`VirtualClock` (fully deterministic latency trajectories) or
  the wall clock; :mod:`repro.traffic.slo` folds the resulting
  ``RequestTiming``s into p50/p95/p99 TTFT + ITL, rejection rate, and
  SLO-goodput (``benchmarks/traffic.py`` sweeps offered load with it).
"""
from repro.traffic.arrivals import (
    ARRIVAL_PROCESSES, bursty_arrivals, poisson_arrivals,
)
from repro.traffic.replay import ReplayResult, VirtualClock, replay_trace
from repro.traffic.scenarios import SUITES, Scenario, suite_max_total_len
from repro.traffic.slo import PERCENTILES, SLOConfig, evaluate
from repro.traffic.trace import (
    TracedRequest, TrafficTrace, generate_trace, parse_trace_spec,
    suite_engine_max_len, trace_max_len,
)

__all__ = [
    "ARRIVAL_PROCESSES", "bursty_arrivals", "poisson_arrivals",
    "ReplayResult", "VirtualClock", "replay_trace",
    "SUITES", "Scenario", "suite_max_total_len",
    "PERCENTILES", "SLOConfig", "evaluate",
    "TracedRequest", "TrafficTrace", "generate_trace", "parse_trace_spec",
    "suite_engine_max_len", "trace_max_len",
]
