"""SLO evaluation: fold per-request timings into a serving scorecard.

The serving layer is judged on *percentile latency at offered load*, not
mean throughput: p50/p95/p99 time-to-first-token (TTFT) and inter-token
latency (ITL), the rejection rate, and **SLO-goodput** — the rate of
requests that completed *and* met their latency bounds (rejected or
SLO-violating work counts for nothing).  This module turns a replayed
trace's :class:`~repro.serve.engine.RequestOutput` list (which carries
the PR4 ``RequestTiming`` events) into exactly that scorecard; the
offered-load sweep in ``benchmarks/traffic.py`` records it per load
point into ``BENCH_traffic.json``.

Percentile conventions: TTFT percentiles are over completed requests'
``ttft_s``; ITL percentiles are over completed requests' ``mean_itl_s``
(per-request mean), with the worst single gap tracked separately as
``itl_max_s``.  A request meets its SLO iff it completed with
``ttft_s <= slo.ttft_s`` **and** ``max_itl_s <= slo.itl_s`` (max, not
mean — a single long stall is a violation the user saw).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import RequestOutput
from repro.serve.faults import CANCEL_CLASS

PERCENTILES = (50, 95, 99)


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Per-request latency bounds (seconds).  ``ttft_s`` bounds submission
    to first token; ``itl_s`` bounds the worst inter-token gap."""

    ttft_s: float
    itl_s: float

    def __post_init__(self):
        if self.ttft_s <= 0:
            raise ValueError(f"ttft_s={self.ttft_s} must be > 0")
        if self.itl_s <= 0:
            raise ValueError(f"itl_s={self.itl_s} must be > 0")

    def met_by(self, out: RequestOutput) -> bool:
        if out.reject_reason is not None or out.fault_reason is not None \
                or out.timing is None:
            return False
        return (out.timing.ttft_s <= self.ttft_s
                and out.timing.max_itl_s <= self.itl_s)


def _pcts(xs: Sequence[float]) -> Dict[str, float]:
    if not xs:
        return {f"p{p}": 0.0 for p in PERCENTILES}
    arr = np.asarray(xs, np.float64)
    return {f"p{p}": float(np.percentile(arr, p)) for p in PERCENTILES}


def evaluate(outputs: Sequence[RequestOutput], duration_s: float,
             slo: Optional[SLOConfig] = None,
             offered_rps: Optional[float] = None) -> Dict:
    """Score one replayed trace.

    ``outputs`` is everything the front-end delivered — completions and
    rejections; ``duration_s`` is the replay span (virtual or wall) used
    as the rate denominator.  Returns a flat JSON-ready dict.
    """
    done: List[RequestOutput] = [
        o for o in outputs
        if o.reject_reason is None and o.fault_reason is None
    ]
    rejected = [o for o in outputs if o.reject_reason is not None]
    # terminal faults (quarantined / shed / deadline / cancelled) are not
    # completions and not rejections: the engine accepted them but could
    # not (or was told not to) finish them — scored separately
    faulted = [o for o in outputs if o.fault_reason is not None]
    by_reason: Dict[str, int] = {}
    for o in rejected:
        by_reason[o.reject_reason] = by_reason.get(o.reject_reason, 0) + 1
    faults_by_reason: Dict[str, int] = {}
    for o in faulted:
        faults_by_reason[o.fault_reason] = faults_by_reason.get(o.fault_reason, 0) + 1
    n_cancelled = sum(1 for o in faulted
                      if o.fault_reason in CANCEL_CLASS)
    ttfts = [o.timing.ttft_s for o in done if o.timing is not None]
    itls = [o.timing.mean_itl_s for o in done if o.timing is not None]
    queue = [o.timing.queue_time_s for o in outputs if o.timing is not None]
    n = len(outputs)
    dur = max(duration_s, 1e-9)
    n_good = sum(1 for o in done if slo.met_by(o)) if slo is not None else len(done)
    rep = {
        "n_offered": n,
        "n_completed": len(done),
        "n_rejected": len(rejected),
        "rejected_by_reason": by_reason,
        "n_faulted": len(faulted) - n_cancelled,
        "n_cancelled": n_cancelled,
        "faulted_by_reason": faults_by_reason,
        "rejection_rate": len(rejected) / max(n, 1),
        "duration_s": duration_s,
        "offered_rps": (offered_rps if offered_rps is not None else n / dur),
        "completed_rps": len(done) / dur,
        "completed_tok_s": sum(o.gen_len for o in done) / dur,
        "queue_p50_s": float(np.percentile(queue, 50)) if queue else 0.0,
        **{f"ttft_{k}_s": v for k, v in _pcts(ttfts).items()},
        **{f"itl_{k}_s": v for k, v in _pcts(itls).items()},
        "itl_max_s": max((o.timing.max_itl_s for o in done
                          if o.timing is not None), default=0.0),
    }
    if slo is not None:
        rep.update({
            "slo_ttft_s": slo.ttft_s,
            "slo_itl_s": slo.itl_s,
            "n_slo_met": n_good,
            "slo_attainment": n_good / max(n, 1),
            "goodput_rps": n_good / dur,
        })
    return rep
