"""Continuous-batching serve engine over the fused scan decode.

The engine owns a fixed grid of ``max_slots`` decode slots backed by one
pre-allocated slotted state pytree (``Model.init_decode_state``).  Requests
with different prompt lengths and generation budgets flow through it:

  queue -> [admit: packed prefill -> scatter into free slots]
        -> [fused decode chunks: one XLA dispatch per chunk]
        -> [retire finished slots -> per-request ASTRA accounting]

Admission and retirement happen between chunks; a chunk never runs past
the earliest-finishing active slot (``steps = min(chunk_steps,
min(remaining))``), so requests join and leave at step granularity and no
slot ever generates beyond its budget.  Slots decode at *different*
absolute positions inside one fused chunk — ``pos`` is a per-slot vector
threaded down to the attention cache writes (``models.attention``).

Inactive slots still ride through the batch (fixed shapes keep one
compiled program); whatever they compute is discarded, and admission
overwrites the slot's entire state before it is ever read.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import lru_cache
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import AstraChipConfig
from repro.core.plan import validate_site_registry
from repro.models.model import Model
from repro.serve.accounting import RequestHardwareReport, request_hardware_report
from repro.serve.decode_loop import make_fused_decode
from repro.serve.prefill import pack_prompts, packed_prefill
from repro.serve.sampling import GREEDY, SamplerConfig, sample_next_token
from repro.serve.slots import scatter_states


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 8
    max_len: int = 256  # pre-allocated per-slot state length
    chunk_steps: int = 8  # fused steps per dispatch (1 = per-step batching)
    sampler: SamplerConfig = GREEDY
    seed: int = 0
    astra_accounting: bool = True


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray  # [S] or [C, S] multi-codebook, int32
    max_new_tokens: int
    eos_id: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[-1])


@dataclasses.dataclass
class RequestOutput:
    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray  # generated tokens [G] (or [C, G])
    wall_time_s: float
    hardware: Optional[RequestHardwareReport] = None

    @property
    def gen_len(self) -> int:
        return int(self.tokens.shape[-1])

    @property
    def full_sequence(self) -> np.ndarray:
        return np.concatenate([self.prompt, self.tokens], axis=-1)


@dataclasses.dataclass
class _Slot:
    req: Request
    pos: int  # absolute position of the next decode write
    remaining: int  # tokens still to generate
    generated: List[np.ndarray]
    t_start: float


@lru_cache(maxsize=256)
def _check_site_registry(cfg) -> None:
    """Executed-GEMM-site <-> simulator-op cross-check, once per config."""
    validate_site_registry(cfg)


class ServeEngine:
    def __init__(self, model: Model, params, config: ServeConfig = ServeConfig(),
                 chip: Optional[AstraChipConfig] = None, plan=None):
        """``plan`` (optional, any ``ExecutionPlan.from_spec`` form) selects
        the execution plan for this engine, overriding the model's own."""
        if plan is not None:
            model = model.with_plan(plan)
        cfg = model.cfg
        # every GEMM site this model executes must resolve 1:1 to a
        # simulator op — the accounting below attributes energy by site
        _check_site_registry(cfg)
        self.model = model
        self.params = params
        self.config = config
        self.chip = chip or AstraChipConfig()
        self._fused = make_fused_decode(model)
        self._queue: deque[Request] = deque()
        self._slots: List[Optional[_Slot]] = [None] * config.max_slots
        self._finished: Dict[int, RequestOutput] = {}
        self._order: List[int] = []
        self._next_id = 0
        self._key = jax.random.PRNGKey(config.seed)
        self._states = model.init_decode_state(config.max_slots, config.max_len)
        tok_shape = ((config.max_slots, cfg.n_codebooks, 1) if cfg.n_codebooks
                     else (config.max_slots, 1))
        self._cur_tok = jnp.zeros(tok_shape, jnp.int32)
        # the full-seq prefill emits window-sized rings; when the window
        # exceeds the pre-allocated max_len the slotted cache is smaller
        # (init_cache clamps), so prefill must go through the scan path
        self._force_scan_prefill = (
            any(k == "local" for k in cfg.layer_kinds) and config.max_len < cfg.window
        )

    # ------------------------------------------------------------- intake
    def submit(self, prompt, max_new_tokens: int, eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.shape[-1] + max_new_tokens > self.config.max_len:
            raise ValueError(
                f"prompt_len {prompt.shape[-1]} + max_new {max_new_tokens} "
                f"exceeds max_len {self.config.max_len}"
            )
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, prompt, max_new_tokens, eos_id)
        self._order.append(rid)
        if max_new_tokens == 0:
            # nothing to decode: complete without ever taking a slot
            self._complete(req, [], time.time())
        else:
            self._queue.append(req)
        return rid

    # ------------------------------------------------------------ engine
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def run(self) -> List[RequestOutput]:
        """Drain queue and slots; outputs in submission order."""
        while self.has_work():
            self.step()
        return [self._finished[rid] for rid in self._order]

    def step(self) -> List[RequestOutput]:
        """Admit + one fused chunk.  Returns requests finished this step."""
        before = set(self._finished)
        self._admit()
        self._decode_chunk()
        return [self._finished[rid] for rid in self._order
                if rid in self._finished and rid not in before]

    # ------------------------------------------------------------- admit
    def _admit(self):
        free = [i for i, s in enumerate(self._slots) if s is None]
        n = min(len(free), len(self._queue))
        if n == 0:
            return
        slots_ids = free[:n]
        reqs = [self._queue.popleft() for _ in range(n)]
        t_start = time.time()
        tokens, lengths = pack_prompts([r.prompt for r in reqs], self.model.cfg)
        last_logits, small_states = packed_prefill(
            self.model, self.params, tokens, lengths, self.config.max_len,
            lengths_static=[r.prompt_len for r in reqs],
            force_scan=self._force_scan_prefill,
        )
        self._key, sub = jax.random.split(self._key)
        first = sample_next_token(last_logits, self.config.sampler, sub, self.model.cfg)
        ids = jnp.asarray(slots_ids, jnp.int32)
        self._states = scatter_states(self._states, small_states, ids)
        self._cur_tok = self._cur_tok.at[ids].set(first)
        first_np = np.asarray(first)  # [n, 1] or [n, C, 1]
        for j, (i, req) in enumerate(zip(slots_ids, reqs)):
            tok0 = first_np[j]  # [1] or [C, 1]
            slot = _Slot(req, pos=req.prompt_len, remaining=req.max_new_tokens - 1,
                         generated=[tok0], t_start=t_start)
            if self._hit_eos(req, tok0) or slot.remaining == 0:
                self._retire(slot)
            else:
                self._slots[i] = slot

    # ------------------------------------------------------------- chunk
    def _decode_chunk(self):
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return
        steps = min(self.config.chunk_steps,
                    min(self._slots[i].remaining for i in active))
        pos = np.zeros(self.config.max_slots, np.int32)
        for i in active:
            pos[i] = self._slots[i].pos
        self._key, sub = jax.random.split(self._key)
        toks, (next_tok, states, _, _) = self._fused(
            self.params, self._cur_tok, self._states, jnp.asarray(pos), sub,
            steps=steps, sampler=self.config.sampler,
        )
        self._states = states
        self._cur_tok = next_tok
        toks_np = np.asarray(toks)  # [B, steps] or [B, C, steps]
        for i in active:
            slot = self._slots[i]
            slot.generated.append(toks_np[i])
            slot.pos += steps
            slot.remaining -= steps
            if slot.remaining == 0 or self._hit_eos(slot.req, toks_np[i]):
                self._retire(slot)
                self._slots[i] = None

    # ------------------------------------------------------------ retire
    def _hit_eos(self, req: Request, toks: np.ndarray) -> bool:
        if req.eos_id is None or toks.ndim > 1:  # no EOS over codebook grids
            return False
        return bool(np.any(toks == req.eos_id))

    def _retire(self, slot: _Slot):
        gen = np.concatenate(slot.generated, axis=-1)
        if slot.req.eos_id is not None and gen.ndim == 1:
            hits = np.nonzero(gen == slot.req.eos_id)[0]
            if hits.size:
                gen = gen[: hits[0] + 1]  # keep the EOS, drop overshoot
        self._complete(slot.req, gen, slot.t_start)

    def _complete(self, req: Request, gen, t_start: float):
        gen = np.asarray(gen, np.int32)
        if gen.size == 0:
            shape = (req.prompt.shape[0], 0) if req.prompt.ndim == 2 else (0,)
            gen = np.zeros(shape, np.int32)
        hw = None
        if self.config.astra_accounting:
            hw = request_hardware_report(
                self.model.cfg, self.chip, req.prompt_len, int(gen.shape[-1])
            )
        self._finished[req.id] = RequestOutput(
            req.id, req.prompt, gen, time.time() - t_start, hw
        )

    # -------------------------------------------------------- convenience
    def generate_batch(self, prompts: Sequence[np.ndarray], max_new_tokens: int,
                       eos_id: Optional[int] = None) -> List[RequestOutput]:
        """Submit a batch and drain — outputs in prompt order."""
        ids = [self.submit(p, max_new_tokens, eos_id) for p in prompts]
        self.run()
        return [self._finished[rid] for rid in ids]
