"""Continuous-batching serve engine over the fused scan decode.

The engine owns a fixed grid of ``max_slots`` decode slots backed by one
pre-allocated slotted state pytree (``Model.init_decode_state``).  Requests
with different prompt lengths and generation budgets flow through it:

  queue -> [admit: packed prefill -> scatter into free slots]
        -> [fused decode chunks: one XLA dispatch per chunk]
        -> [retire finished slots -> per-request ASTRA accounting]

Admission and retirement happen between chunks; a chunk never runs past
the earliest-finishing active slot (``steps = min(chunk_steps,
min(remaining))``), so requests join and leave at step granularity and no
slot ever generates beyond its budget.  Slots decode at *different*
absolute positions inside one fused chunk — ``pos`` is a per-slot vector
threaded down to the attention cache writes (``models.attention``).

Inactive slots still ride through the batch (fixed shapes keep one
compiled program); whatever they compute is discarded, and admission
overwrites the slot's entire state before it is ever read.

KV memory comes in two layouts (docs/SERVING.md):

* **dense** (``kv_block_size=0``) — one max-length cache per slot, the
  legacy layout;
* **paged** (``kv_block_size>0``) — attn/local KV lives in fixed-size
  blocks drawn from a global pool (``serve/kv_pool.py``) addressed through
  per-slot block tables, with a radix-tree **prefix cache**
  (``serve/prefix_tree.py``): a request whose prompt prefix matches
  interned blocks skips prefill for them (pure global-attention stacks),
  reuses the KV verbatim, and bills those tokens at zero modeled ASTRA
  cost.  Inactive slots' table rows point at the scratch block, so their
  ride-along writes land nowhere readable.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import lru_cache
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import AstraChipConfig
from repro.core.plan import validate_site_registry
from repro.models.attention import BlockTables
from repro.models.model import Model
from repro.serve.accounting import RequestHardwareReport, request_hardware_report
from repro.serve.decode_loop import make_fused_decode
from repro.serve.kv_pool import KVBlockPool
from repro.serve.prefill import pack_prompts, packed_prefill, prefill_paged_suffix
from repro.serve.prefix_tree import RadixPrefixTree
from repro.serve.sampling import GREEDY, SamplerConfig, sample_next_token
from repro.serve.slots import paged_scatter_states, scatter_states

_paged_scatter = jax.jit(paged_scatter_states)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 8
    max_len: int = 256  # pre-allocated per-slot state length
    chunk_steps: int = 8  # fused steps per dispatch (1 = per-step batching)
    sampler: SamplerConfig = GREEDY
    seed: int = 0
    astra_accounting: bool = True
    # paged KV cache (docs/SERVING.md): 0 keeps the dense per-slot layout;
    # >0 stores attn/local KV in blocks of this many positions
    kv_block_size: int = 0
    # physical pool blocks incl. scratch; 0 = auto (slot floor + 2 slots'
    # worth of prefix-cache headroom)
    kv_pool_blocks: int = 0
    # radix-tree prefix reuse (paged + pure global-attention stacks only)
    prefix_cache: bool = True


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray  # [S] or [C, S] multi-codebook, int32
    max_new_tokens: int
    eos_id: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[-1])


@dataclasses.dataclass
class RequestOutput:
    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray  # generated tokens [G] (or [C, G])
    wall_time_s: float
    hardware: Optional[RequestHardwareReport] = None

    @property
    def gen_len(self) -> int:
        return int(self.tokens.shape[-1])

    @property
    def full_sequence(self) -> np.ndarray:
        return np.concatenate([self.prompt, self.tokens], axis=-1)


@dataclasses.dataclass
class _Slot:
    req: Request
    pos: int  # absolute position of the next decode write
    remaining: int  # tokens still to generate
    generated: List[np.ndarray]
    t_start: float
    cached: int = 0  # prompt tokens served from the prefix cache


@lru_cache(maxsize=256)
def _check_site_registry(cfg) -> None:
    """Executed-GEMM-site <-> simulator-op cross-check, once per config."""
    validate_site_registry(cfg)


def _kv_deterministic(model: Model) -> bool:
    """Whether interned KV is a pure function of the token path.

    Prefix reuse replays blocks computed under an earlier batch packing,
    so every executed GEMM site must run exact or with a *static*
    (PTQ-calibrated) activation scale — dynamic per-tensor scales depend
    on what else was packed into the prefill, which would make outputs
    vary with admission history (DESIGN.md §Numerics and parity).
    """
    from repro.core.plan import model_sites

    for s in model_sites(model.cfg):
        cc = model.plan.resolve(s)
        if cc.mode != "exact" and cc.act_scale is None:
            return False
    return True


class ServeEngine:
    def __init__(self, model: Model, params, config: ServeConfig = ServeConfig(),
                 chip: Optional[AstraChipConfig] = None, plan=None):
        """``plan`` (optional, any ``ExecutionPlan.from_spec`` form) selects
        the execution plan for this engine, overriding the model's own."""
        if plan is not None:
            model = model.with_plan(plan)
        cfg = model.cfg
        # every GEMM site this model executes must resolve 1:1 to a
        # simulator op — the accounting below attributes energy by site
        _check_site_registry(cfg)
        self.model = model
        self.params = params
        self.config = config
        self.chip = chip or AstraChipConfig()
        self._fused = make_fused_decode(model)
        self._queue: deque[Request] = deque()
        self._slots: List[Optional[_Slot]] = [None] * config.max_slots
        self._finished: Dict[int, RequestOutput] = {}
        self._order: List[int] = []
        self._next_id = 0
        self._key = jax.random.PRNGKey(config.seed)
        # ----------------------------------------------------- KV layout
        self._paged = (config.kv_block_size > 0
                       and any(k in ("attn", "local") for k in cfg.layer_kinds))
        self._pool: Optional[KVBlockPool] = None
        self._prefix: Optional[RadixPrefixTree] = None
        if self._paged:
            bs = config.kv_block_size
            w = -(-config.max_len // bs)
            # pool-capacity arithmetic, checked HERE so admission can never
            # deadlock mid-decode: even with every other slot full, a new
            # request must always find its blocks after evicting the tree
            floor = 1 + config.max_slots * w
            n_blocks = config.kv_pool_blocks or (floor + 2 * w)
            if n_blocks < floor:
                raise ValueError(
                    f"kv_pool_blocks={n_blocks} cannot back max_slots="
                    f"{config.max_slots} x ceil(max_len {config.max_len} / "
                    f"kv_block_size {bs}) = {w} blocks each (+1 scratch): "
                    f"need >= {floor}"
                )
            self._block_size, self._table_width = bs, w
            self._pool = KVBlockPool(n_blocks, bs)
            self._slot_blocks: List[List[int]] = [[] for _ in range(config.max_slots)]
            self._tables_np = np.zeros((config.max_slots, w), np.int32)
            self._tables_dev = jnp.asarray(self._tables_np)
            self._tables_dirty = False
            self._ring_len = (min(config.max_len, cfg.window)
                              if any(k == "local" for k in cfg.layer_kinds) else 0)
            # prefix reuse needs every stateful layer's state to be
            # reconstructible from pooled blocks -> pure global attention
            self._suffix_path = all(k == "attn" for k in cfg.layer_kinds)
            if config.prefix_cache and self._suffix_path and _kv_deterministic(model):
                self._prefix = RadixPrefixTree(bs)
            self._states = model.init_decode_state(
                config.max_slots, config.max_len, paged=(n_blocks, bs)
            )
        else:
            self._states = model.init_decode_state(config.max_slots, config.max_len)
        tok_shape = ((config.max_slots, cfg.n_codebooks, 1) if cfg.n_codebooks
                     else (config.max_slots, 1))
        self._cur_tok = jnp.zeros(tok_shape, jnp.int32)
        # the full-seq prefill emits window-sized rings; when the window
        # exceeds the pre-allocated max_len the slotted cache is smaller
        # (init_cache clamps), so prefill must go through the scan path
        self._force_scan_prefill = (
            any(k == "local" for k in cfg.layer_kinds) and config.max_len < cfg.window
        )

    # ------------------------------------------------------------- intake
    def submit(self, prompt, max_new_tokens: int, eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.shape[-1] + max_new_tokens > self.config.max_len:
            raise ValueError(
                f"prompt_len {prompt.shape[-1]} + max_new {max_new_tokens} "
                f"exceeds max_len {self.config.max_len}"
            )
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, prompt, max_new_tokens, eos_id)
        self._order.append(rid)
        if max_new_tokens == 0:
            # nothing to decode: complete without ever taking a slot
            self._complete(req, [], time.time())
        else:
            self._queue.append(req)
        return rid

    # ------------------------------------------------------------ engine
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def run(self) -> List[RequestOutput]:
        """Drain queue and slots; outputs in submission order."""
        while self.has_work():
            self.step()
        return [self._finished[rid] for rid in self._order]

    def step(self) -> List[RequestOutput]:
        """Admit + one fused chunk.  Returns requests finished this step."""
        before = set(self._finished)
        self._admit()
        self._decode_chunk()
        return [self._finished[rid] for rid in self._order
                if rid in self._finished and rid not in before]

    # ------------------------------------------------------------- admit
    def _admit(self):
        free = [i for i, s in enumerate(self._slots) if s is None]
        n = min(len(free), len(self._queue))
        if n == 0:
            return
        slots_ids = free[:n]
        reqs = [self._queue.popleft() for _ in range(n)]
        t_start = time.time()
        if self._paged:
            last_logits, cached = self._prefill_paged(slots_ids, reqs)
        else:
            last_logits = self._prefill_dense(slots_ids, reqs)
            cached = [0] * n
        self._key, sub = jax.random.split(self._key)
        first = sample_next_token(last_logits, self.config.sampler, sub, self.model.cfg)
        ids = jnp.asarray(slots_ids, jnp.int32)
        self._cur_tok = self._cur_tok.at[ids].set(first)
        first_np = np.asarray(first)  # [n, 1] or [n, C, 1]
        for j, (i, req) in enumerate(zip(slots_ids, reqs)):
            tok0 = first_np[j]  # [1] or [C, 1]
            slot = _Slot(req, pos=req.prompt_len, remaining=req.max_new_tokens - 1,
                         generated=[tok0], t_start=t_start, cached=cached[j])
            if self._hit_eos(req, tok0) or slot.remaining == 0:
                self._retire(slot)
                self._release_blocks(i)
            else:
                self._slots[i] = slot

    def _packed_prefill_small(self, reqs: List[Request]):
        """Cold prefill of ``reqs`` at batch len(reqs) with dense states."""
        tokens, lengths = pack_prompts([r.prompt for r in reqs], self.model.cfg)
        return packed_prefill(
            self.model, self.params, tokens, lengths, self.config.max_len,
            lengths_static=[r.prompt_len for r in reqs],
            force_scan=self._force_scan_prefill,
        )

    def _prefill_dense(self, slots_ids: List[int], reqs: List[Request]):
        last_logits, small_states = self._packed_prefill_small(reqs)
        ids = jnp.asarray(slots_ids, jnp.int32)
        self._states = scatter_states(self._states, small_states, ids)
        return last_logits

    def _prefill_paged(self, slots_ids: List[int], reqs: List[Request]):
        """Allocate block tables (reusing interned prefix blocks), prefill
        the unmatched work, and intern the new prompt blocks."""
        bs, w = self._block_size, self._table_width
        starts: List[int] = []
        for i, req in zip(slots_ids, reqs):
            total = -(-(req.prompt_len + req.max_new_tokens) // bs)
            matched: List[int] = []
            if self._prefix is not None:
                # always leave >= 1 suffix token: the last prompt token's
                # logits seed the first sampled token
                matched = self._prefix.match(
                    req.prompt, max_blocks=min((req.prompt_len - 1) // bs, total)
                )
                for blk in matched:
                    self._pool.incref(blk)
            need = total - len(matched)
            if need > self._pool.n_free and self._prefix is not None:
                self._prefix.evict(need - self._pool.n_free, self._pool)
            blocks = matched + self._pool.alloc(need)
            self._slot_blocks[i] = blocks
            self._tables_np[i] = 0
            self._tables_np[i, : len(blocks)] = blocks
            starts.append(len(matched) * bs)
        self._tables_dirty = True
        rows_dev = jnp.asarray(self._tables_np[slots_ids])
        if self._suffix_path:
            suffixes = [r.prompt[..., s:] for r, s in zip(reqs, starts)]
            tokens, lengths = pack_prompts(suffixes, self.model.cfg)
            need_blocks = max(
                -(-(s + int(tokens.shape[-1])) // bs) for s in starts
            )
            ctx = 1
            while ctx < need_blocks:
                ctx *= 2  # pow2 buckets bound the jit-compile count
            ctx = min(ctx, w)
            last_logits, self._states = prefill_paged_suffix(
                self.model, self.params, tokens, lengths, self._states,
                rows_dev, jnp.asarray(starts, jnp.int32), ctx,
            )
        else:
            last_logits, small_states = self._packed_prefill_small(reqs)
            self._states = _paged_scatter(
                self._states, small_states, jnp.asarray(slots_ids, jnp.int32), rows_dev
            )
        if self._prefix is not None:
            for i, req, start in zip(slots_ids, reqs, starts):
                nb_full = req.prompt_len // bs
                if nb_full > start // bs:
                    self._prefix.insert(req.prompt[..., : nb_full * bs],
                                        self._slot_blocks[i][:nb_full], self._pool)
        return last_logits, starts

    def _release_blocks(self, slot_i: int):
        if not self._paged or not self._slot_blocks[slot_i]:
            return
        for blk in self._slot_blocks[slot_i]:
            self._pool.decref(blk)
        self._slot_blocks[slot_i] = []
        # retired rows point back at scratch so the slot's ride-along
        # decode writes can't corrupt a future owner of these blocks
        self._tables_np[slot_i] = 0
        self._tables_dirty = True

    def _block_tables(self) -> BlockTables:
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(self._tables_np)
            self._tables_dirty = False
        return BlockTables(self._tables_dev, jnp.int32(self._ring_len))

    # ------------------------------------------------------------- chunk
    def _decode_chunk(self):
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return
        steps = min(self.config.chunk_steps,
                    min(self._slots[i].remaining for i in active))
        pos = np.zeros(self.config.max_slots, np.int32)
        for i in active:
            pos[i] = self._slots[i].pos
        self._key, sub = jax.random.split(self._key)
        toks, (next_tok, states, _, _) = self._fused(
            self.params, self._cur_tok, self._states, jnp.asarray(pos), sub,
            steps=steps, sampler=self.config.sampler,
            tables=self._block_tables() if self._paged else None,
        )
        self._states = states
        self._cur_tok = next_tok
        toks_np = np.asarray(toks)  # [B, steps] or [B, C, steps]
        for i in active:
            slot = self._slots[i]
            slot.generated.append(toks_np[i])
            slot.pos += steps
            slot.remaining -= steps
            if slot.remaining == 0 or self._hit_eos(slot.req, toks_np[i]):
                self._retire(slot)
                self._release_blocks(i)
                self._slots[i] = None

    # ------------------------------------------------------------ retire
    def _hit_eos(self, req: Request, toks: np.ndarray) -> bool:
        if req.eos_id is None or toks.ndim > 1:  # no EOS over codebook grids
            return False
        return bool(np.any(toks == req.eos_id))

    def _retire(self, slot: _Slot):
        gen = np.concatenate(slot.generated, axis=-1)
        if slot.req.eos_id is not None and gen.ndim == 1:
            hits = np.nonzero(gen == slot.req.eos_id)[0]
            if hits.size:
                gen = gen[: hits[0] + 1]  # keep the EOS, drop overshoot
        self._complete(slot.req, gen, slot.t_start, cached=slot.cached)

    def _complete(self, req: Request, gen, t_start: float, cached: int = 0):
        gen = np.asarray(gen, np.int32)
        if gen.size == 0:
            shape = (req.prompt.shape[0], 0) if req.prompt.ndim == 2 else (0,)
            gen = np.zeros(shape, np.int32)
        hw = None
        if self.config.astra_accounting:
            hw = request_hardware_report(
                self.model.cfg, self.chip, req.prompt_len, int(gen.shape[-1]),
                cached_prompt_len=cached,
            )
        self._finished[req.id] = RequestOutput(
            req.id, req.prompt, gen, time.time() - t_start, hw
        )

    # ---------------------------------------------------------- prefix stats
    @property
    def prefix_stats(self) -> Dict[str, int]:
        """Radix-tree/pool counters (empty when the prefix cache is off)."""
        if self._prefix is None:
            return {}
        t = self._prefix
        return {
            "hits": t.hits, "misses": t.misses, "hit_tokens": t.hit_tokens,
            "evictions": t.evictions, "interned_blocks": len(t),
            "free_blocks": self._pool.n_free,
        }

    # -------------------------------------------------------- convenience
    def generate_batch(self, prompts: Sequence[np.ndarray], max_new_tokens: int,
                       eos_id: Optional[int] = None) -> List[RequestOutput]:
        """Submit a batch and drain — outputs in prompt order."""
        ids = [self.submit(p, max_new_tokens, eos_id) for p in prompts]
        self.run()
        return [self._finished[rid] for rid in ids]
