"""Continuous-batching serve engine over the fused scan decode.

The engine owns a fixed grid of ``max_slots`` decode slots backed by one
pre-allocated slotted state pytree (``Model.init_decode_state``).  Requests
with different prompt lengths and generation budgets flow through it:

  queue -> [admit: claim a free slot]
        -> [prefill: full-prompt (blocking) or bounded chunks (scheduler)]
        -> [fused decode chunks: one XLA dispatch per chunk]
        -> [retire finished slots -> per-request ASTRA accounting + timing]

Admission and retirement happen between chunks; a chunk never runs past
the earliest-finishing active slot (``steps = min(chunk_steps,
min(remaining))``), so requests join and leave at step granularity and no
slot ever generates beyond its budget.  Slots decode at *different*
absolute positions inside one fused chunk — ``pos`` is a per-slot vector
threaded down to the attention cache writes (``models.attention``).

Inactive slots still ride through the batch (fixed shapes keep one
compiled program); whatever they compute is discarded, and admission
overwrites the slot's entire state before it is ever read.

**Prefill scheduling** comes in two modes (docs/SERVING.md §Scheduling):

* **blocking** (``prefill_chunk_tokens=0``) — admission runs the full
  packed prompt prefill before the next decode chunk; one long prompt
  stalls every active slot's token stream for the whole prefill.
* **chunked** (``prefill_chunk_tokens>0``) — admitted requests hold their
  slot in the ``PREFILLING`` state while their prompt is fed in bounded
  chunks interleaved with decode chunks (``serve/scheduler.py``: FCFS,
  decode priority, shared per-round token budget).  Dense layouts chunk
  through the windowed masked scan (``prefill.prefill_window``); paged
  pure-attention stacks chunk through ``prefill_paged_suffix`` — a
  partially-prefilled request is just a request whose resident prefix is
  its own earlier chunks.  Paged *stateful* stacks (recurrent/windowed)
  fall back to blocking admission: their decode state cannot be resumed
  from pooled blocks (same constraint as the prefix cache).

KV memory comes in two layouts (docs/SERVING.md):

* **dense** (``kv_block_size=0``) — one max-length cache per slot, the
  legacy layout;
* **paged** (``kv_block_size>0``) — attn/local KV lives in fixed-size
  blocks drawn from a global pool (``serve/kv_pool.py``) addressed through
  per-slot block tables, with a radix-tree **prefix cache**
  (``serve/prefix_tree.py``): a request whose prompt prefix matches
  interned blocks skips prefill for them (pure global-attention stacks),
  reuses the KV verbatim, and bills those tokens at zero modeled ASTRA
  cost.  Inactive slots' table rows point at the scratch block, so their
  ride-along writes land nowhere readable.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import lru_cache
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import AstraChipConfig
from repro.core.plan import validate_site_registry
from repro.models.attention import BlockTables
from repro.models.model import Model
from repro.serve.clock import resolve_clock
from repro.serve.accounting import (
    RequestHardwareReport, RequestTiming, request_hardware_report, request_timing,
)
from repro.serve.decode_loop import make_fused_decode
from repro.serve.faults import (
    CANCEL_CLASS, CANCELLED, FAULT_NONFINITE, FAULT_POOL_PRESSURE,
    FAULT_STEP_ERROR, FaultSpec, InjectedStepError, NonFiniteLogitsError,
)
from repro.serve.kv_pool import KVBlockPool
from repro.serve.prefill import (
    pack_prompts, packed_prefill, prefill_paged_suffix, prefill_window,
)
from repro.serve.prefix_tree import RadixPrefixTree
from repro.serve.sampling import GREEDY, SamplerConfig, sample_next_token
from repro.serve.scheduler import (
    DegradedLadder, SchedulerConfig, TokenBudgetScheduler, pow2_bucket,
)
from repro.serve.slots import SlotState, paged_scatter_states, scatter_states

_paged_scatter = jax.jit(paged_scatter_states)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 8
    max_len: int = 256  # pre-allocated per-slot state length
    chunk_steps: int = 8  # fused steps per dispatch (1 = per-step batching)
    sampler: SamplerConfig = GREEDY
    seed: int = 0
    astra_accounting: bool = True
    # paged KV cache (docs/SERVING.md): 0 keeps the dense per-slot layout;
    # >0 stores attn/local KV in blocks of this many positions
    kv_block_size: int = 0
    # physical pool blocks incl. scratch; 0 = auto (slot floor + 2 slots'
    # worth of prefix-cache headroom)
    kv_pool_blocks: int = 0
    # radix-tree prefix reuse (paged + pure global-attention stacks only)
    prefix_cache: bool = True
    # chunked-prefill scheduler (docs/SERVING.md §Scheduling): per-round
    # token budget shared between decode (priority) and prefill; 0 keeps
    # the blocking full-prompt admission
    prefill_chunk_tokens: int = 0
    # attention implementation (docs/SERVING.md §Decode-attention memory
    # model): "naive" = jnp einsum (gathered logical view on paged
    # layouts); "flash" = Pallas kernels — gather-free streaming decode /
    # suffix prefill over the block table, flash full-sequence prefill.
    # None inherits the model's own ModelOptions.attn_impl; a string
    # overrides it for this engine.
    attn_impl: Optional[str] = None
    # KV pool storage dtype (docs/SERVING.md §KV quantization): "none"
    # keeps pool blocks in model dtype; "int8" stores them quantized
    # against the plan's calibrated per-KV-head static scales (requires
    # the paged layout and a calibrated, KV-deterministic plan — the
    # engine raises ValueError otherwise instead of silently degrading).
    # None inherits ModelOptions.kv_quant; a string overrides it.
    kv_quant: Optional[str] = None
    # degraded-mode ladder (docs/SERVING.md §Fault tolerance): on repeated
    # paged-admission pool pressure the engine flushes the prefix tree,
    # then disables prefix admission, then sheds the queue head as a
    # terminal "pool_pressure" fault output.  False restores the old
    # fail-loud behaviour (RuntimeError when wedged).
    degraded_mode: bool = True


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray  # [S] or [C, S] multi-codebook, int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    t_submit: float = 0.0  # stamped by ServeEngine.submit — queue wait and
    # wall time are measured from here, not from admission

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[-1])


@dataclasses.dataclass
class RequestOutput:
    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray  # generated tokens [G] (or [C, G])
    wall_time_s: float  # submit -> completion, true end to end
    hardware: Optional[RequestHardwareReport] = None
    timing: Optional[RequestTiming] = None  # queue/TTFT/ITL breakdown
    # set by the admission front-end (serve/frontend.py) when the request
    # was refused instead of served: "queue_full" | "queue_timeout".
    # Rejected requests still get this terminal output — they never
    # silently vanish — with empty tokens and queue-wait-only timing.
    reject_reason: Optional[str] = None
    # set when the request was terminated by the fault layer instead of
    # completing: a fault class from serve/faults.py ("step_error" |
    # "nonfinite_logits" | "pool_pressure") or a client-intent reason
    # ("cancelled" | "deadline_exceeded").  ``tokens`` holds whatever was
    # generated (and streamed) before termination.
    fault_reason: Optional[str] = None

    @property
    def gen_len(self) -> int:
        return int(self.tokens.shape[-1])

    @property
    def full_sequence(self) -> np.ndarray:
        return np.concatenate([self.prompt, self.tokens], axis=-1)


@dataclasses.dataclass
class _Slot:
    req: Request
    state: SlotState
    pos: int = 0  # absolute position of the next decode write
    remaining: int = 0  # tokens still to generate
    filled: int = 0  # prompt tokens resident (prefix-cached or prefilled)
    generated: List[np.ndarray] = dataclasses.field(default_factory=list)
    cached: int = 0  # prompt tokens served from the prefix cache
    t_admit: float = 0.0
    t_first: float = 0.0
    # token-arrival events [(host_time, n_tokens)] — one per fused chunk
    events: List[Tuple[float, int]] = dataclasses.field(default_factory=list)


@lru_cache(maxsize=256)
def _check_site_registry(cfg) -> None:
    """Executed-GEMM-site <-> simulator-op cross-check, once per config."""
    validate_site_registry(cfg)


def _kv_deterministic(model: Model) -> bool:
    """Whether interned KV is a pure function of the token path.

    Prefix reuse replays blocks computed under an earlier batch packing,
    so every executed GEMM site must run exact or with a *static*
    (PTQ-calibrated) activation scale — dynamic per-tensor scales depend
    on what else was packed into the prefill, which would make outputs
    vary with admission history (DESIGN.md §Numerics and parity).
    """
    from repro.core.plan import model_sites

    for s in model_sites(model.cfg):
        cc = model.plan.resolve(s)
        if cc.mode != "exact" and cc.act_scale is None:
            return False
    return True


def kv_quant_reject_reason(model: Model, kv_block_size: int) -> Optional[str]:
    """Why ``kv_quant="int8"`` cannot run on this engine (None = legal).

    Shared between ``ServeEngine.__init__`` (which raises ``ValueError``
    with this reason) and the serving CLI (which surfaces it next to the
    flag that caused it).  The checks encode the KV-determinism
    discipline (docs/SERVING.md §KV quantization): pooled int8 blocks are
    replayed by the prefix cache, so their contents must be a pure
    function of the token path — static calibrated scales only.
    """
    if kv_block_size <= 0:
        return (
            "kv_quant='int8' requires the paged KV layout "
            "(kv_block_size > 0): dense per-slot caches stay in model "
            "dtype (docs/SERVING.md §KV quantization)"
        )
    if not _kv_deterministic(model):
        return (
            "kv_quant='int8' requires deterministic KV: every quantized "
            "GEMM site must carry a static calibrated act_scale — "
            "dynamic per-tensor scales would make pooled int8 blocks "
            "depend on admission history; run Model.calibrate or use an "
            "exact/static plan (docs/SERVING.md §KV quantization)"
        )
    from repro.core.plan import kv_sites

    missing = [s for s in kv_sites(model.cfg) if model.plan.kv_scale(s) is None]
    if missing:
        return (
            f"kv_quant='int8' needs calibrated KV scales but the plan "
            f"carries none for {missing[0]!r}"
            + (f" (+{len(missing) - 1} more site(s))" if len(missing) > 1 else "")
            + "; run Model.calibrate before enabling kv_quant"
        )
    return None


def _pool_bytes_per_block(states) -> int:
    """Storage bytes one physical block occupies summed across every
    layer's K+V pools (at the pools' actual dtype — int8 under
    ``kv_quant``).  Per-pool scale vectors are constants, not per-block
    storage, and are excluded."""
    from repro.models.attention import PagedKVCache, QuantPagedKVCache

    total = 0
    for node in jax.tree.leaves(
        states, is_leaf=lambda x: isinstance(x, (PagedKVCache, QuantPagedKVCache))
    ):
        if not isinstance(node, (PagedKVCache, QuantPagedKVCache)):
            continue
        for arr in (node.k, node.v):
            # units pools are [U, n_blocks, kv, bs, hd], remainder pools
            # [n_blocks, kv, bs, hd]
            n_blocks = arr.shape[1] if arr.ndim == 5 else arr.shape[0]
            total += arr.size * arr.dtype.itemsize // n_blocks
    return total


class ServeEngine:
    def __init__(self, model: Model, params, config: Optional[ServeConfig] = None,
                 chip: Optional[AstraChipConfig] = None, plan=None,
                 clock: Optional[Callable[[], float]] = None,
                 token_sink: Optional[Callable[[int, np.ndarray], None]] = None):
        """``plan`` (optional, any ``ExecutionPlan.from_spec`` form) selects
        the execution plan for this engine, overriding the model's own.

        ``clock`` (optional) replaces the ambient wall clock
        (:data:`repro.serve.clock.wall_clock`) for every timestamp the
        engine takes (submission, admission, token arrivals, completion) —
        the traffic replay harness injects a virtual clock here so latency
        trajectories are deterministic (docs/SERVING.md §Traffic).

        ``token_sink`` (optional) is the incremental drain path: called as
        ``sink(request_id, tokens)`` the moment generated tokens exist on
        the host — the first sampled token at admission, then one call per
        fused decode chunk (EOS-trimmed, so the concatenation of a
        request's sink calls is exactly its final ``RequestOutput.tokens``).
        Finished outputs still flow through the ``run()``/``step()`` outbox
        exactly once; the sink only adds early visibility.
        """
        # None sentinel, not a default instance: a module-level default
        # would be one shared (frozen, but identity-bearing) object across
        # every engine — the B006 discipline the lint baseline enforces
        config = ServeConfig() if config is None else config
        if plan is not None:
            model = model.with_plan(plan)
        if (config.attn_impl is not None
                and config.attn_impl != model.opts.attn_impl):
            # the engine owns the serving execution options: without this
            # override no Pallas attention path is reachable from serving
            # (callers habitually pass Model(cfg) with default opts).
            # ModelOptions.__post_init__ validates the value.
            model = dataclasses.replace(
                model, opts=dataclasses.replace(model.opts,
                                                attn_impl=config.attn_impl)
            )
        if (config.kv_quant is not None
                and config.kv_quant != model.opts.kv_quant):
            # same ownership rule as attn_impl: the engine picks the KV
            # storage dtype.  ModelOptions.__post_init__ validates the value.
            model = dataclasses.replace(
                model, opts=dataclasses.replace(model.opts,
                                                kv_quant=config.kv_quant)
            )
        if model.opts.kv_quant != "none":
            reason = kv_quant_reject_reason(model, config.kv_block_size)
            if reason is not None:
                # refuse loudly — a silently-disabled quantized pool would
                # report fp16-sized capacity while claiming int8 savings
                raise ValueError(reason)
        cfg = model.cfg
        # every GEMM site this model executes must resolve 1:1 to a
        # simulator op — the accounting below attributes energy by site
        _check_site_registry(cfg)
        self.model = model
        self.params = params
        self.config = config
        self.chip = chip or AstraChipConfig()
        self.clock = resolve_clock(clock)
        self.token_sink = token_sink
        self._fused = make_fused_decode(model)
        self._queue: deque[Request] = deque()
        self._slots: List[Optional[_Slot]] = [None] * config.max_slots
        self._outbox: List[RequestOutput] = []  # finished, not yet collected
        self._next_id = 0
        self._key = jax.random.PRNGKey(config.seed)
        # ---------------------------------------------- fault containment
        self._step_no = 0  # engine rounds run (fault/ladder attribution)
        self._n_quarantined = 0  # slots terminated by quarantine_slot
        self._n_cancelled = 0    # requests ended by cancel/deadline
        self._n_shed = 0         # queue heads shed by the degraded ladder
        # prefix reuse / chunked paged prefill need every stateful layer's
        # state to be reconstructible from pooled blocks -> pure global attn
        self._suffix_path = all(k == "attn" for k in cfg.layer_kinds)
        # ----------------------------------------------------- KV layout
        self._paged = (config.kv_block_size > 0
                       and any(k in ("attn", "local") for k in cfg.layer_kinds))
        self._pool: Optional[KVBlockPool] = None
        self._prefix: Optional[RadixPrefixTree] = None
        if self._paged:
            bs = config.kv_block_size
            w = -(-config.max_len // bs)
            # pool-capacity arithmetic, checked HERE so admission can never
            # deadlock mid-decode: even with every other slot full, a new
            # request must always find its blocks after evicting the tree
            floor = 1 + config.max_slots * w
            n_blocks = config.kv_pool_blocks or (floor + 2 * w)
            if n_blocks < floor:
                raise ValueError(
                    f"kv_pool_blocks={n_blocks} cannot back max_slots="
                    f"{config.max_slots} x ceil(max_len {config.max_len} / "
                    f"kv_block_size {bs}) = {w} blocks each (+1 scratch): "
                    f"need >= {floor}"
                )
            self._block_size, self._table_width = bs, w
            self._pool = KVBlockPool(n_blocks, bs)
            self._slot_blocks: List[List[int]] = [[] for _ in range(config.max_slots)]
            self._tables_np = np.zeros((config.max_slots, w), np.int32)
            self._tables_dev = jnp.asarray(self._tables_np)
            self._tables_dirty = False
            self._ring_len = (min(config.max_len, cfg.window)
                              if any(k == "local" for k in cfg.layer_kinds) else 0)
            # record *why* reuse is off instead of silently dropping it —
            # kv_stats and the CLI surface this next to the pool counters
            self._prefix_off_reason: Optional[str] = None
            if not config.prefix_cache:
                self._prefix_off_reason = "disabled by config (prefix_cache=False)"
            elif not self._suffix_path:
                self._prefix_off_reason = (
                    "stateful stack: recurrent/windowed layers cannot resume "
                    "from pooled blocks"
                )
            elif not _kv_deterministic(model):
                self._prefix_off_reason = (
                    "non-deterministic KV: a quantized GEMM site runs with "
                    "dynamic scales (run Model.calibrate for static scales)"
                )
            else:
                self._prefix = RadixPrefixTree(bs)
            self._states = model.init_decode_state(
                config.max_slots, config.max_len, paged=(n_blocks, bs)
            )
            # byte accounting: one block's footprint summed across every
            # layer's K+V pools, at the pool's actual storage dtype
            self._pool.bytes_per_block = _pool_bytes_per_block(self._states)
        else:
            self._states = model.init_decode_state(config.max_slots, config.max_len)
        # degraded-mode ladder: pool pressure is a paged-only phenomenon
        # (dense layouts have no pool to squeeze), and only meaningful
        # when the operator hasn't opted back into fail-loud wedging
        self._ladder: Optional[DegradedLadder] = (
            DegradedLadder() if (self._paged and config.degraded_mode) else None)
        self._prefix_admission = True  # ladder level 2 turns this off
        self._admit_progress = False   # >=1 request left the queue this round
        # --------------------------------------------- prefill scheduling
        self._sched: Optional[TokenBudgetScheduler] = None
        self._prefilling: List[int] = []  # PREFILLING slot ids, admission order
        self._admit_stalled = False  # paged admission rolled back this round
        if config.prefill_chunk_tokens < 0:
            raise ValueError(
                f"prefill_chunk_tokens={config.prefill_chunk_tokens} is "
                "negative; pass a per-round token budget or 0 for blocking "
                "admission"
            )
        if config.prefill_chunk_tokens > 0:
            if self._paged and not self._suffix_path:
                # stateful stacks cannot resume recurrent/ring state from
                # pooled blocks mid-prompt; their paged mode admits one-shot
                # (the dense layout of the same arch chunks fine)
                self._sched = None
            else:
                self._sched = TokenBudgetScheduler(
                    SchedulerConfig(config.prefill_chunk_tokens))
        tok_shape = ((config.max_slots, cfg.n_codebooks, 1) if cfg.n_codebooks
                     else (config.max_slots, 1))
        self._cur_tok = jnp.zeros(tok_shape, jnp.int32)
        # the full-seq prefill emits window-sized rings; when the window
        # exceeds the pre-allocated max_len the slotted cache is smaller
        # (init_cache clamps), so prefill must go through the scan path
        self._force_scan_prefill = (
            any(k == "local" for k in cfg.layer_kinds) and config.max_len < cfg.window
        )

    # ------------------------------------------------------------- intake
    def check_request(self, prompt, max_new_tokens: int) -> np.ndarray:
        """Canonicalize and validate a request; returns the int32 prompt.

        Shared with the admission front-end (serve/frontend.py) so invalid
        requests raise at intake — before a queue position or engine id is
        ever taken."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.shape[-1] == 0:
            raise ValueError("empty prompt: a request needs at least one "
                             "prompt token (its logits seed sampling)")
        if max_new_tokens < 0:
            raise ValueError(f"max_new_tokens={max_new_tokens} is negative")
        if prompt.shape[-1] + max_new_tokens > self.config.max_len:
            raise ValueError(
                f"prompt_len {prompt.shape[-1]} + max_new {max_new_tokens} "
                f"exceeds max_len {self.config.max_len}"
            )
        return prompt

    def allocate_request_id(self) -> int:
        """Reserve the next request id without enqueueing anything — the
        front-end ids requests at *its* admission time so a later reject
        and a served request share one id space."""
        rid = self._next_id
        self._next_id += 1
        return rid

    def submit(self, prompt, max_new_tokens: int, eos_id: Optional[int] = None,
               request_id: Optional[int] = None,
               t_submit: Optional[float] = None) -> int:
        """Enqueue a request.  ``request_id`` (from ``allocate_request_id``)
        and ``t_submit`` let the front-end keep its own admission time as
        the latency anchor — queue/TTFT then include front-end backpressure
        waits, not just the engine-side queue."""
        prompt = self.check_request(prompt, max_new_tokens)
        rid = self.allocate_request_id() if request_id is None else request_id
        req = Request(rid, prompt, max_new_tokens, eos_id,
                      t_submit=self.clock() if t_submit is None else t_submit)
        if max_new_tokens == 0:
            # nothing to decode: complete without ever taking a slot
            now = self.clock()
            self._complete(req, [], t_admit=now, t_first=now, events=[])
        else:
            self._queue.append(req)
        return rid

    # ------------------------------------------------------------ engine
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def run(self) -> List[RequestOutput]:
        """Drain queue and slots; returns every output completed since the
        last collection (``run``/``step``), in submission order.

        Outputs are handed over exactly once — a long-lived engine does
        not accumulate history, and interleaved callers each see only the
        work finished since they last collected.
        """
        outs = self._drain()
        while self.has_work():
            outs.extend(self.step())
        return sorted(outs, key=lambda o: o.request_id)

    def step(self, faults: Optional[Sequence[FaultSpec]] = None) -> List[RequestOutput]:
        """Admit + prefill work + one fused chunk.  Drains and returns the
        requests that finished since the last collection.

        ``faults`` (normally passed by :class:`~repro.serve.supervisor.
        EngineSupervisor`) injects decode faults into this round's chunk:
        a ``step_error`` raises :class:`InjectedStepError` *before* any
        state commit, a ``nonfinite_logits`` poisons the victim slot's
        logits inside the fused scan.  Either way the raised
        :class:`~repro.serve.faults.ServeFault` names the implicated
        slots and every other slot's stream stays bit-identical to a
        fault-free replay; without a supervisor the fault propagates to
        the caller (loud by design)."""
        self._step_no += 1
        self._admit()
        if self._sched is not None:
            self._prefill_chunk()
        self._decode_chunk(faults)
        self._check_progress()
        return self._drain()

    def _drain(self) -> List[RequestOutput]:
        outs, self._outbox = self._outbox, []
        return outs

    def _check_progress(self):
        """React to a stalled paged-admission round.

        With ``degraded_mode`` (default) the engine walks the
        :class:`~repro.serve.scheduler.DegradedLadder` — flush the prefix
        tree, then stop prefix admission, then shed the queue head as a
        terminal ``pool_pressure`` fault output — and relaxes one level
        per round with admission progress.  With ``degraded_mode=False``
        it keeps the original fail-loud contract: raise when admission
        can never succeed (possible only when pool invariants were broken
        externally — the construction-time floor makes organic admission
        infallible)."""
        if self._admit_stalled and self._ladder is not None:
            self._degrade()
        elif (self._admit_stalled and self._queue
                and not any(s is not None for s in self._slots)):
            raise RuntimeError(
                "serve engine wedged: paged admission failed with every slot "
                "free, so no retirement can ever release blocks "
                f"({len(self._queue)} request(s) queued, "
                f"{self._pool.n_free} pool blocks free)"
            )
        elif self._admit_progress and self._ladder is not None:
            if self._ladder.relax(self._step_no) == DegradedLadder.NORMAL:
                self._prefix_admission = True
        self._admit_stalled = False
        self._admit_progress = False

    def _degrade(self):
        """One stalled round: escalate the ladder and act at its level."""
        level = self._ladder.escalate(self._step_no)
        if level >= DegradedLadder.FLUSH_PREFIX and self._prefix is not None:
            # free every evictable interned block — cache value traded
            # for admission headroom, hits become recomputes, not faults
            self._prefix.evict(self._pool.n_blocks, self._pool)
        if level >= DegradedLadder.NO_PREFIX_ADMISSION:
            self._prefix_admission = False
        if level >= DegradedLadder.SHED_LOAD and self._queue:
            # bounded: one queue head per stalled round becomes a terminal
            # pool_pressure fault output (retryable once pressure clears)
            req = self._queue.popleft()
            now = self.clock()
            self._complete(req, [], t_admit=now, t_first=now, events=[],
                           fault_reason=FAULT_POOL_PRESSURE)
            self._n_shed += 1

    # ------------------------------------------------------------- admit
    def _admit(self):
        free = [i for i, s in enumerate(self._slots) if s is None]
        n = min(len(free), len(self._queue))
        if n == 0:
            return
        before = len(self._queue)
        if self._sched is not None:
            self._admit_chunked(free[:n])
        else:
            self._admit_blocking(free[:n])
        if len(self._queue) < before:
            self._admit_progress = True

    def _reserve_blocks(self, req: Request) -> Tuple[List[int], int]:
        """Match + incref prefix blocks and allocate the rest for ``req``.

        Returns (blocks, n_matched).  Atomic: if the pool cannot cover the
        allocation (a forced evict shortfall — impossible under the
        construction-time floor unless the pool was tampered with), every
        incref taken here is rolled back before the ``RuntimeError``
        propagates, so the caller can re-queue the request with no leaked
        refcounts.
        """
        bs = self._block_size
        total = -(-(req.prompt_len + req.max_new_tokens) // bs)
        matched: List[int] = []
        if self._prefix is not None and self._prefix_admission:
            # always leave >= 1 suffix token: the last prompt token's
            # logits seed the first sampled token
            matched = self._prefix.match(
                req.prompt, max_blocks=min((req.prompt_len - 1) // bs, total)
            )
            for blk in matched:
                self._pool.incref(blk)
        need = total - len(matched)
        try:
            if need > self._pool.n_free and self._prefix is not None:
                self._prefix.evict(need - self._pool.n_free, self._pool)
            fresh = self._pool.alloc(need)
        except RuntimeError:
            for blk in matched:
                self._pool.decref(blk)
            raise
        return matched + fresh, len(matched)

    def _install_blocks(self, slot_i: int, blocks: List[int],
                        into_table: bool) -> None:
        """Record a slot's blocks; materialize its table row only when the
        slot is (or is about to be) visible to decode — a PREFILLING slot's
        row stays at scratch so ride-along decode writes land nowhere."""
        self._slot_blocks[slot_i] = blocks
        self._tables_np[slot_i] = 0
        if into_table:
            self._tables_np[slot_i, : len(blocks)] = blocks
        self._tables_dirty = True

    # ------------------------------------------------- blocking admission
    def _admit_blocking(self, slot_ids: List[int]):
        reqs = [self._queue.popleft() for _ in range(len(slot_ids))]
        t_admit = self.clock()
        if self._paged:
            slot_ids, reqs, last_logits, cached = self._prefill_paged(slot_ids, reqs)
            if not reqs:
                return
        else:
            last_logits = self._prefill_dense(slot_ids, reqs)
            cached = [0] * len(reqs)
        self._key, sub = jax.random.split(self._key)
        first = sample_next_token(last_logits, self.config.sampler, sub, self.model.cfg)
        ids = jnp.asarray(slot_ids, jnp.int32)
        self._cur_tok = self._cur_tok.at[ids].set(first)
        first_np = np.asarray(first)  # [n, 1] or [n, C, 1]
        t_first = self.clock()
        for j, (i, req) in enumerate(zip(slot_ids, reqs)):
            tok0 = first_np[j]  # [1] or [C, 1]
            slot = _Slot(req, SlotState.DECODING, pos=req.prompt_len,
                         remaining=req.max_new_tokens - 1, filled=req.prompt_len,
                         generated=[tok0], cached=cached[j], t_admit=t_admit,
                         t_first=t_first, events=[(t_first, 1)])
            self._emit_tokens(req, tok0)
            if self._hit_eos(req, tok0) or slot.remaining == 0:
                self._retire(slot)
                self._release_blocks(i)
            else:
                self._slots[i] = slot

    def _packed_prefill_small(self, reqs: List[Request]):
        """Cold prefill of ``reqs`` at batch len(reqs) with dense states."""
        tokens, lengths = pack_prompts([r.prompt for r in reqs], self.model.cfg)
        return packed_prefill(
            self.model, self.params, tokens, lengths, self.config.max_len,
            lengths_static=[r.prompt_len for r in reqs],
            force_scan=self._force_scan_prefill,
        )

    def _prefill_dense(self, slots_ids: List[int], reqs: List[Request]):
        last_logits, small_states = self._packed_prefill_small(reqs)
        ids = jnp.asarray(slots_ids, jnp.int32)
        self._states = scatter_states(self._states, small_states, ids)
        return last_logits

    def _prefill_paged(self, slot_ids: List[int], reqs: List[Request]):
        """Allocate block tables (reusing interned prefix blocks), prefill
        the unmatched work, and intern the new prompt blocks.

        Exception-safe: if a request's blocks cannot be covered (forced
        evict shortfall), its increfs are rolled back and it — plus every
        later popped request, preserving FCFS order — is re-queued at the
        front; the requests admitted before it proceed normally.
        """
        bs, w = self._block_size, self._table_width
        starts: List[int] = []
        adm_slots: List[int] = []
        adm_reqs: List[Request] = []
        for k, (i, req) in enumerate(zip(slot_ids, reqs)):
            try:
                blocks, n_matched = self._reserve_blocks(req)
            except RuntimeError:
                for r in reversed(reqs[k:]):
                    self._queue.appendleft(r)
                self._admit_stalled = True
                break
            self._install_blocks(i, blocks, into_table=True)
            starts.append(n_matched * bs)
            adm_slots.append(i)
            adm_reqs.append(req)
        if not adm_reqs:
            return [], [], None, []
        rows_dev = jnp.asarray(self._tables_np[adm_slots])
        if self._suffix_path:
            suffixes = [r.prompt[..., s:] for r, s in zip(adm_reqs, starts)]
            tokens, lengths = pack_prompts(suffixes, self.model.cfg)
            ctx = self._ctx_bucket(max(
                s + int(tokens.shape[-1]) for s in starts
            ))
            last_logits, self._states = prefill_paged_suffix(
                self.model, self.params, tokens, lengths, self._states,
                rows_dev, jnp.asarray(starts, jnp.int32), ctx,
            )
        else:
            last_logits, small_states = self._packed_prefill_small(adm_reqs)
            self._states = _paged_scatter(
                self._states, small_states, jnp.asarray(adm_slots, jnp.int32),
                rows_dev
            )
        if self._prefix is not None:
            for i, req, start in zip(adm_slots, adm_reqs, starts):
                self._intern_prompt(i, req, start)
        return adm_slots, adm_reqs, last_logits, starts

    def _intern_prompt(self, slot_i: int, req: Request, start: int):
        if not self._prefix_admission:  # ladder level 2+: no new interning
            return
        bs = self._block_size
        nb_full = req.prompt_len // bs
        if nb_full > start // bs:
            self._prefix.insert(req.prompt[..., : nb_full * bs],
                                self._slot_blocks[slot_i][:nb_full], self._pool)

    def _ctx_bucket(self, max_pos: int) -> int:
        """Pow2 context-view width (blocks) covering ``max_pos`` positions —
        bounds the jit-compile count of the suffix prefill."""
        need = -(-max_pos // self._block_size)
        return max(pow2_bucket(need, self._table_width), 1)

    # -------------------------------------------------- chunked admission
    def _admit_chunked(self, slot_ids: List[int]):
        """Claim free slots for waiting requests as PREFILLING — no prefill
        work here; the scheduler feeds their prompts in bounded chunks."""
        t_admit = self.clock()
        new_dense: List[int] = []
        for i in slot_ids:
            if not self._queue:
                break
            req = self._queue[0]
            filled = 0
            if self._paged:
                try:
                    blocks, n_matched = self._reserve_blocks(req)
                except RuntimeError:
                    # FCFS: the head can't fit — don't admit later requests
                    # over it; retry once retirements free blocks
                    self._admit_stalled = True
                    break
                # table row stays at scratch until the slot starts DECODING:
                # ride-along decode writes must not touch its real blocks
                self._install_blocks(i, blocks, into_table=False)
                filled = n_matched * self._block_size
            self._queue.popleft()
            self._slots[i] = _Slot(req, SlotState.PREFILLING, filled=filled,
                                   cached=filled, t_admit=t_admit)
            self._prefilling.append(i)
            if not self._paged:
                new_dense.append(i)
        if new_dense:
            # dense chunked prefill builds the slot state *in place*, so the
            # previous occupant's state must be zeroed (recurrent leaves
            # especially; KV positions are rewritten in prompt order anyway)
            zeros = self.model.init_decode_state(len(new_dense), self.config.max_len)
            self._states = scatter_states(self._states, zeros,
                                          jnp.asarray(new_dense, jnp.int32))

    def _prefill_chunk(self):
        """One bounded prefill dispatch: the scheduler's FCFS chunk plan
        for this round, then DECODING transitions for completed prompts."""
        if not self._prefilling:
            return
        n_active = sum(1 for s in self._slots
                       if s is not None and s.state is SlotState.DECODING)
        needs = [(i, self._slots[i].req.prompt_len - self._slots[i].filled)
                 for i in self._prefilling]
        plan = self._sched.plan_chunks(needs, n_active)
        if not plan:
            return
        if self._paged:
            last_logits = self._prefill_chunk_paged(plan)  # [n_sel, 1, ...]
            row_of = {i: j for j, (i, _) in enumerate(plan)}
        else:
            last_logits = self._prefill_chunk_dense(plan)  # [B, 1, ...]
            row_of = {i: i for i, _ in plan}
        done: List[int] = []
        for i, take in plan:
            slot = self._slots[i]
            slot.filled += take
            if slot.filled == slot.req.prompt_len:
                done.append(i)
        if done:
            self._start_decoding(done, last_logits, [row_of[i] for i in done])

    def _chunk_tokens(self, plan: List[Tuple[int, int]], width: int,
                      rows: Optional[List[int]] = None) -> np.ndarray:
        """Pack each planned slot's next prompt slice into a ``[n, width]``
        (or ``[n, C, width]``) grid.  ``rows`` maps plan entries to grid
        rows (defaults to 0..n-1)."""
        cfg = self.model.cfg
        n = len(plan) if rows is None else self.config.max_slots
        shape = (n, cfg.n_codebooks, width) if cfg.n_codebooks else (n, width)
        toks = np.zeros(shape, np.int32)
        for j, (i, take) in enumerate(plan):
            slot = self._slots[i]
            r = j if rows is None else rows[j]
            toks[r, ..., :take] = slot.req.prompt[..., slot.filled:slot.filled + take]
        return toks

    def _prefill_chunk_paged(self, plan: List[Tuple[int, int]]):
        """Chunked suffix prefill against the paged pool: each selected
        slot's resident prefix is its prefix-cache hit plus its own earlier
        chunks (``starts`` need not be block-aligned)."""
        width = pow2_bucket(max(t for _, t in plan),
                            self.config.prefill_chunk_tokens)
        tokens = jnp.asarray(self._chunk_tokens(plan, width))
        starts = [self._slots[i].filled for i, _ in plan]
        lengths = jnp.asarray([t for _, t in plan], jnp.int32)
        rows_dev = jnp.asarray(np.stack([
            self._real_row(i) for i, _ in plan
        ]))
        ctx = self._ctx_bucket(max(s + width for s in starts))
        last_logits, self._states = prefill_paged_suffix(
            self.model, self.params, tokens, lengths, self._states,
            rows_dev, jnp.asarray(starts, jnp.int32), ctx,
        )
        return last_logits

    def _real_row(self, slot_i: int) -> np.ndarray:
        row = np.zeros(self._table_width, np.int32)
        blocks = self._slot_blocks[slot_i]
        row[: len(blocks)] = blocks
        return row

    def _prefill_chunk_dense(self, plan: List[Tuple[int, int]]):
        """Chunked dense prefill: one windowed masked scan over the full
        engine state — selected slots advance, everything else is gated."""
        width = pow2_bucket(max(t for _, t in plan),
                            self.config.prefill_chunk_tokens)
        b = self.config.max_slots
        tokens = jnp.asarray(
            self._chunk_tokens(plan, width, rows=[i for i, _ in plan]))
        starts = np.zeros(b, np.int32)
        lengths = np.zeros(b, np.int32)
        for i, take in plan:
            starts[i] = self._slots[i].filled
            lengths[i] = take
        last_logits, self._states = prefill_window(
            self.model, self.params, tokens, jnp.asarray(starts),
            jnp.asarray(lengths), self._states,
        )
        return last_logits

    def _start_decoding(self, slot_ids: List[int], last_logits, rows: List[int]):
        """PREFILLING -> DECODING: sample each completed prompt's first
        token, expose paged table rows, intern prefix blocks."""
        self._key, sub = jax.random.split(self._key)
        logits = last_logits[jnp.asarray(rows, jnp.int32)]
        first = sample_next_token(logits, self.config.sampler, sub, self.model.cfg)
        ids = jnp.asarray(slot_ids, jnp.int32)
        self._cur_tok = self._cur_tok.at[ids].set(first)
        first_np = np.asarray(first)
        t_first = self.clock()
        for j, i in enumerate(slot_ids):
            slot = self._slots[i]
            req = slot.req
            tok0 = first_np[j]
            slot.state = SlotState.DECODING
            slot.pos = req.prompt_len
            slot.remaining = req.max_new_tokens - 1
            slot.generated = [tok0]
            slot.t_first = t_first
            slot.events = [(t_first, 1)]
            self._emit_tokens(req, tok0)
            self._prefilling.remove(i)
            if self._paged:
                self._install_blocks(i, self._slot_blocks[i], into_table=True)
                if self._prefix is not None:
                    self._intern_prompt(i, req, slot.cached)
            if self._hit_eos(req, tok0) or slot.remaining == 0:
                self._retire(slot)
                self._release_blocks(i)
                self._slots[i] = None

    # ------------------------------------------------------ paged helpers
    def _release_blocks(self, slot_i: int):
        if not self._paged or not self._slot_blocks[slot_i]:
            return
        for blk in self._slot_blocks[slot_i]:
            self._pool.decref(blk)
        self._slot_blocks[slot_i] = []
        # retired rows point back at scratch so the slot's ride-along
        # decode writes can't corrupt a future owner of these blocks
        self._tables_np[slot_i] = 0
        self._tables_dirty = True

    def _block_tables(self) -> BlockTables:
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(self._tables_np)
            self._tables_dirty = False
        return BlockTables(self._tables_dev, jnp.int32(self._ring_len))

    # -------------------------------------------------- fault containment
    def quarantine_slot(self, slot_i: int, reason: str,
                        scrub: bool = True) -> None:
        """Terminate the request occupying ``slot_i`` as a terminal fault.

        The request's already-generated tokens become its final output
        (``fault_reason=reason`` — the streamed chunks and the output
        tokens stay equal, exactly like a normal retire), its exclusively
        held pool blocks are scrubbed (NaN containment: attention masks
        *scores*, not values, so ``0 * NaN`` would poison a future owner's
        output) and released, and the slot is freed.  No other slot is
        touched — that is the whole point.
        """
        slot = self._slots[slot_i]
        if slot is None:
            raise ValueError(f"quarantine of empty slot {slot_i}")
        if slot.state is SlotState.PREFILLING:
            self._prefilling.remove(slot_i)
        gen = (np.concatenate(slot.generated, axis=-1)
               if slot.generated else [])
        now = self.clock()
        self._complete(slot.req, gen, slot.t_admit or now,
                       slot.t_first or slot.t_admit or now, slot.events,
                       cached=slot.cached, fault_reason=reason)
        if scrub and self._paged:
            # only blocks nobody else holds: shared (interned) blocks are
            # prompt prefill output — deterministic and never written by
            # this slot's decode, so they cannot carry its poison
            self._scrub_blocks([b for b in self._slot_blocks[slot_i]
                                if self._pool.ref(b) == 1])
        self._release_blocks(slot_i)
        self._slots[slot_i] = None
        if reason in CANCEL_CLASS:
            self._n_cancelled += 1
        else:
            self._n_quarantined += 1

    def _scrub_blocks(self, blocks: List[int]) -> None:
        """Zero the given physical blocks in every layer's K/V pools.

        Dense layouts need no analogue: admission fully overwrites a
        slot's state before it is ever read (``scatter_states``), and the
        finite guard only inspects active slots.
        """
        if not blocks:
            return
        from repro.models.attention import PagedKVCache, QuantPagedKVCache

        idx = jnp.asarray(blocks, jnp.int32)

        def scrub(node):
            if isinstance(node, (PagedKVCache, QuantPagedKVCache)):
                def z(arr):
                    # units pools [U, n_blocks, kv, bs, hd]; rem [n_blocks, ...]
                    return (arr.at[:, idx].set(0) if arr.ndim == 5
                            else arr.at[idx].set(0))
                return node._replace(k=z(node.k), v=z(node.v))
            return node

        self._states = jax.tree.map(
            scrub, self._states,
            is_leaf=lambda x: isinstance(x, (PagedKVCache, QuantPagedKVCache)),
        )

    def cancel(self, request_id: int, reason: str = CANCELLED) -> bool:
        """Terminate a queued or in-flight request (client intent).

        Mid-decode cancellation goes through :meth:`quarantine_slot`, so
        the request's KV blocks are released immediately — freeing pool
        capacity is the point of cancelling.  The terminal output (tokens
        generated so far, ``fault_reason=reason``) flows through the
        normal outbox.  Returns False when the id is not queued or
        in-flight (already finished, or never seen).
        """
        for j, req in enumerate(self._queue):
            if req.id == request_id:
                del self._queue[j]
                now = self.clock()
                self._complete(req, [], t_admit=now, t_first=now, events=[],
                               fault_reason=reason)
                self._n_cancelled += 1
                return True
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.req.id == request_id:
                # client-intent termination never poisoned anything — the
                # slot decoded finite tokens until now — so skip the scrub
                self.quarantine_slot(i, reason, scrub=False)
                return True
        return False

    def audit(self, external_refs: Sequence[int] = ()) -> Dict[str, object]:
        """Cross-check every piece of serving bookkeeping; raise on drift.

        Verifies (a) outbox/queue/slot request-id disjointness and
        exactly-once outbox discipline, (b) the PREFILLING list against
        slot states, and — on paged layouts — (c) every block's refcount
        against its actual holders (slot tables + prefix tree +
        ``external_refs``, e.g. a supervisor's pool-pressure holds),
        (d) pool free-list consistency, and (e) device block-table rows
        against host slot state.  Raises ``RuntimeError`` on the first
        violation; returns a report dict (``leaked_blocks``/``leaked_bytes``
        are always 0 when it returns) for tests and stats.
        """
        out_ids = [o.request_id for o in self._outbox]
        if len(set(out_ids)) != len(out_ids):
            raise RuntimeError(
                f"audit: duplicate request ids in outbox ({out_ids})")
        live_ids = {s.req.id for s in self._slots if s is not None}
        live_ids |= {r.id for r in self._queue}
        stale = set(out_ids) & live_ids
        if stale:
            raise RuntimeError(
                f"audit: request id(s) {sorted(stale)} are simultaneously "
                "finished (outbox) and live (queue/slot)")
        for i in self._prefilling:
            s = self._slots[i]
            if s is None or s.state is not SlotState.PREFILLING:
                raise RuntimeError(
                    f"audit: prefilling list names slot {i} but the slot "
                    f"is {'empty' if s is None else s.state}")
        report: Dict[str, object] = {
            "paged": self._paged,
            "slots_live": sum(s is not None for s in self._slots),
            "queued": len(self._queue),
            "outbox": len(out_ids),
            "leaked_blocks": 0,
            "leaked_bytes": 0,
        }
        if not self._paged:
            return report
        self._pool.check_consistent()
        expected: Dict[int, int] = {}
        for blocks in self._slot_blocks:
            for b in blocks:
                expected[b] = expected.get(b, 0) + 1
        tree_blocks = (self._prefix.interned_blocks()
                       if self._prefix is not None else [])
        for b in tree_blocks:
            expected[b] = expected.get(b, 0) + 1
        for b in external_refs:
            expected[b] = expected.get(b, 0) + 1
        drift = [(b, self._pool.ref(b), expected.get(b, 0))
                 for b in range(1, self._pool.n_blocks)
                 if self._pool.ref(b) != expected.get(b, 0)]
        if drift:
            b, have, want = drift[0]
            raise RuntimeError(
                f"audit: {len(drift)} block(s) with refcount drift — e.g. "
                f"block {b}: pool ref {have} vs {want} actual holder(s) "
                "(slot tables + prefix tree + external refs)")
        for i, slot in enumerate(self._slots):
            row = self._tables_np[i]
            blocks = self._slot_blocks[i]
            if slot is None and blocks:
                raise RuntimeError(
                    f"audit: empty slot {i} still holds blocks {blocks}")
            if slot is None or slot.state is SlotState.PREFILLING:
                if row.any():
                    raise RuntimeError(
                        f"audit: slot {i} "
                        f"({'empty' if slot is None else 'PREFILLING'}) has "
                        "a non-scratch device table row — ride-along decode "
                        "writes could corrupt another slot's blocks")
            else:
                want_row = np.zeros_like(row)
                want_row[: len(blocks)] = blocks
                if not np.array_equal(row, want_row):
                    raise RuntimeError(
                        f"audit: slot {i} device table row {row.tolist()} "
                        f"!= host blocks {blocks}")
        report.update(
            pool_blocks=self._pool.n_blocks, live_blocks=self._pool.n_live,
            free_blocks=self._pool.n_free, tree_blocks=len(tree_blocks),
            external_refs=len(list(external_refs)),
        )
        return report

    # ------------------------------------------------------------- chunk
    @staticmethod
    def _resolve_victim(hint: Optional[int], active: List[int]) -> int:
        """Map a FaultSpec slot *hint* onto a slot active this round, so
        seeded schedules stay meaningful whatever the admission pattern."""
        return active[0] if hint is None else active[hint % len(active)]

    def _decode_chunk(self, faults: Optional[Sequence[FaultSpec]] = None):
        active = [i for i, s in enumerate(self._slots)
                  if s is not None and s.state is SlotState.DECODING]
        if not active:
            return
        for spec in (faults or ()):
            if spec.kind == FAULT_STEP_ERROR:
                # whole-dispatch failure, raised BEFORE any state commit:
                # healthy slots simply skip one chunk (under greedy
                # sampling their token streams are chunk-boundary
                # independent, so they stay bit-identical)
                victim = self._resolve_victim(spec.slot, active)
                raise InjectedStepError(
                    f"injected device error at engine step {self._step_no} "
                    f"(slot {victim})", slots=(victim,))
        poison = None
        poisoned = sorted({self._resolve_victim(s.slot, active)
                           for s in (faults or ()) if s.kind == FAULT_NONFINITE})
        if poisoned:
            p = np.zeros(self.config.max_slots, bool)
            p[poisoned] = True
            poison = jnp.asarray(p)
        steps = min(self.config.chunk_steps,
                    min(self._slots[i].remaining for i in active))
        pos = np.zeros(self.config.max_slots, np.int32)
        for i in active:
            pos[i] = self._slots[i].pos
        mask = None
        if (self._sched is not None and not self._paged
                and len(active) < sum(s is not None for s in self._slots)):
            # dense + PREFILLING slots present: gate ride-along state
            # updates so half-prefilled recurrent/KV state stays intact
            m = np.zeros(self.config.max_slots, bool)
            m[active] = True
            mask = jnp.asarray(m)
        self._key, sub = jax.random.split(self._key)
        toks, finite, (next_tok, states, _, _) = self._fused(
            self.params, self._cur_tok, self._states, jnp.asarray(pos), sub,
            steps=steps, sampler=self.config.sampler,
            tables=self._block_tables() if self._paged else None,
            active=mask, poison=poison,
        )
        self._states = states
        self._cur_tok = next_tok
        toks_np = np.asarray(toks)  # [B, steps] or [B, C, steps]
        finite_np = np.asarray(finite)  # [B] bool, ANDed over the chunk
        bad = [i for i in active if not finite_np[i]]
        t_now = self.clock()
        for i in active:
            if i in bad:
                # the slot's tokens this chunk are garbage (sampled from
                # non-finite logits): don't emit or account them — the
                # request ends at its pre-fault stream via quarantine
                continue
            slot = self._slots[i]
            slot.generated.append(toks_np[i])
            slot.events.append((t_now, steps))
            self._emit_tokens(slot.req, toks_np[i])
            slot.pos += steps
            slot.remaining -= steps
            if slot.remaining == 0 or self._hit_eos(slot.req, toks_np[i]):
                self._retire(slot)
                self._release_blocks(i)
                self._slots[i] = None
        if bad:
            # healthy slots are fully committed above; the fault names
            # exactly the poisoned slots (injected or organic NaN alike)
            raise NonFiniteLogitsError(
                f"non-finite logits at engine step {self._step_no} for "
                f"slot(s) {bad}", slots=tuple(bad))

    # ------------------------------------------------------------ retire
    def _hit_eos(self, req: Request, toks: np.ndarray) -> bool:
        if req.eos_id is None or toks.ndim > 1:  # no EOS over codebook grids
            return False
        return bool(np.any(toks == req.eos_id))

    def _trim_eos(self, req: Request, toks: np.ndarray) -> np.ndarray:
        """Clip a token chunk at the request's first EOS (inclusive) —
        the same truncation ``_retire`` applies to the concatenated output,
        so streamed chunks match the final tokens exactly."""
        if req.eos_id is None or toks.ndim > 1:
            return toks
        hits = np.nonzero(toks == req.eos_id)[0]
        return toks[: hits[0] + 1] if hits.size else toks

    def _emit_tokens(self, req: Request, toks: np.ndarray) -> None:
        """Incremental drain: push freshly generated host tokens to the
        registered sink (EOS-trimmed).  The sink sees every request's
        tokens exactly once, in order; finished ``RequestOutput``s still
        go through the outbox."""
        if self.token_sink is not None:
            toks = self._trim_eos(req, toks)
            if toks.shape[-1]:
                self.token_sink(req.id, toks)

    def _retire(self, slot: _Slot):
        gen = np.concatenate(slot.generated, axis=-1)
        if slot.req.eos_id is not None and gen.ndim == 1:
            hits = np.nonzero(gen == slot.req.eos_id)[0]
            if hits.size:
                gen = gen[: hits[0] + 1]  # keep the EOS, drop overshoot
        # EOS can truncate mid-chunk: reconcile the final arrival event so
        # the timing token count matches the tokens actually delivered
        overshoot = sum(n for _, n in slot.events) - int(gen.shape[-1])
        if overshoot > 0 and slot.events:
            t_last, n_last = slot.events[-1]
            slot.events[-1] = (t_last, n_last - overshoot)
        self._complete(slot.req, gen, slot.t_admit, slot.t_first, slot.events,
                       cached=slot.cached)

    def _complete(self, req: Request, gen, t_admit: float, t_first: float,
                  events: List[Tuple[float, int]], cached: int = 0,
                  fault_reason: Optional[str] = None):
        gen = np.asarray(gen, np.int32)
        if gen.size == 0:
            shape = (req.prompt.shape[0], 0) if req.prompt.ndim == 2 else (0,)
            gen = np.zeros(shape, np.int32)
        hw = None
        if self.config.astra_accounting:
            hw = request_hardware_report(
                self.model.cfg, self.chip, req.prompt_len, int(gen.shape[-1]),
                cached_prompt_len=cached,
            )
        timing = request_timing(req.t_submit, t_admit, t_first, events, self.clock())
        self._outbox.append(RequestOutput(
            req.id, req.prompt, gen, timing.wall_time_s, hw, timing,
            fault_reason=fault_reason,
        ))

    # ------------------------------------------------------------- stats
    @property
    def prefix_stats(self) -> Dict[str, int]:
        """Radix-tree/pool counters (empty when the prefix cache is off)."""
        if self._prefix is None:
            return {}
        t = self._prefix
        return {
            "hits": t.hits, "misses": t.misses, "hit_tokens": t.hit_tokens,
            "evictions": t.evictions, "interned_blocks": len(t),
            "free_blocks": self._pool.n_free,
        }

    @property
    def kv_stats(self) -> Dict[str, object]:
        """KV-memory layout counters (docs/SERVING.md §KV quantization);
        ``{}`` on the dense layout.  ``bytes_per_block`` is the storage
        footprint of one physical block summed over every layer's K+V
        pools at their actual dtype — int8 pools report ~half the fp16
        figure, which is exactly the capacity claim BENCH_kv_quant
        checks.  ``prefix_cache_off_reason`` explains a disabled prefix
        cache instead of letting reuse vanish silently."""
        if not self._paged:
            return {}
        out: Dict[str, object] = {
            "kv_quant": self.model.opts.kv_quant,
            "block_size": self._block_size,
            "pool_blocks": self._pool.n_blocks,
            "live_blocks": self._pool.n_live,
            "free_blocks": self._pool.n_free,
            "bytes_per_block": self._pool.bytes_per_block,
            "pool_bytes": self._pool.total_bytes,
            "live_bytes": self._pool.live_bytes,
            "prefix_cache": self._prefix is not None,
        }
        if self._prefix is None and self._prefix_off_reason:
            out["prefix_cache_off_reason"] = self._prefix_off_reason
        if self._ladder is not None:
            out["degraded_level"] = self._ladder.level_name
            out["degraded_transitions"] = len(self._ladder.transitions)
            out["prefix_admission"] = self._prefix_admission
        return out

    @property
    def scheduler_stats(self) -> Dict[str, int]:
        """Chunked-prefill counters; ``{"active": False}`` under blocking
        admission (including the paged-stateful fallback)."""
        if self._sched is None:
            return {"active": False}
        return {"active": True, **self._sched.stats}

    def stats(self) -> Dict[str, object]:
        """One-call serving snapshot: fault/degraded counters plus the
        per-subsystem stat dicts (docs/SERVING.md §Fault tolerance)."""
        return {
            "step": self._step_no,
            "queued": len(self._queue),
            "slots_live": sum(s is not None for s in self._slots),
            "n_quarantined": self._n_quarantined,
            "n_cancelled": self._n_cancelled,
            "n_shed": self._n_shed,
            "degraded_level": (self._ladder.level_name
                               if self._ladder is not None else "normal"),
            "degraded_transitions": (list(self._ladder.transitions)
                                     if self._ladder is not None else []),
            "kv": self.kv_stats,
            "prefix": self.prefix_stats,
            "scheduler": self.scheduler_stats,
        }

    # -------------------------------------------------------- convenience
    def generate_batch(self, prompts: Sequence[np.ndarray], max_new_tokens: int,
                       eos_id: Optional[int] = None) -> List[RequestOutput]:
        """Submit a batch and drain — outputs in prompt order.

        Collects (and discards) any outputs still pending from earlier
        interleaved submissions; callers mixing APIs should use
        ``submit`` + ``run``/``step`` directly.
        """
        ids = [self.submit(p, max_new_tokens, eos_id) for p in prompts]
        by_id = {o.request_id: o for o in self.run()}
        return [by_id[rid] for rid in ids]
