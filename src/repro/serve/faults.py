"""Deterministic fault injection for the serving stack.

ASTRA's stochastic photonic datapath is noisy by construction, and a
production serving tier cannot assume a decode step always succeeds: the
device can throw (the XLA analogue of a link/laser fault), analog noise
can push logits non-finite, the KV pool can be squeezed by a co-tenant,
and a step can simply run slow.  This module gives those failure modes
*names* and a seeded, replayable schedule so the whole fault story —
quarantine, retry, degraded mode (docs/SERVING.md §Fault tolerance) —
is testable on the virtual clock with zero ambient randomness.

Fault classes (``FaultSpec.kind``):

* ``step_error``       — the fused decode dispatch raises before any
  state is committed (stands in for an XLA/device error).  Retryable.
* ``nonfinite_logits`` — NaN is injected into one slot's logits inside
  the fused scan; the per-chunk finite guard attributes it to the right
  slot.  Retryable (models transient analog noise).
* ``pool_pressure``    — the supervisor allocates and holds free KV
  blocks for ``duration`` engine steps, forcing admission shortfalls
  and exercising the degraded-mode ladder.  Retryable (shed requests
  can be resubmitted once pressure clears).
* ``slow_step``        — the (virtual) clock advances by ``delay_s``
  before the step runs; latency metrics feel it, tokens do not.

The injector itself never touches the engine: :class:`EngineSupervisor`
(serve/supervisor.py) pops the specs due at each step and routes them —
decode faults into ``ServeEngine.step(faults=...)``, pressure/slow-step
faults around it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

FAULT_STEP_ERROR = "step_error"
FAULT_NONFINITE = "nonfinite_logits"
FAULT_POOL_PRESSURE = "pool_pressure"
FAULT_SLOW_STEP = "slow_step"
FAULT_KINDS: Tuple[str, ...] = (
    FAULT_STEP_ERROR, FAULT_NONFINITE, FAULT_POOL_PRESSURE, FAULT_SLOW_STEP,
)

# terminal reasons originating from the *client* side rather than a fault
CANCELLED = "cancelled"
DEADLINE_EXCEEDED = "deadline_exceeded"

# fault classes worth re-submitting: transient by construction.  The
# cancel class is deliberate client intent — never retried.
RETRYABLE_FAULTS = frozenset({FAULT_STEP_ERROR, FAULT_NONFINITE,
                              FAULT_POOL_PRESSURE})
CANCEL_CLASS = frozenset({CANCELLED, DEADLINE_EXCEEDED})


class ServeFault(RuntimeError):
    """A per-step serving fault attributable to specific slots.

    ``slots`` names the engine slot indices implicated; every other slot
    committed (or never started) this step and stays bit-identical to a
    fault-free replay.  Without a supervisor these propagate loudly —
    silent degradation is exactly what the swallowed-exceptions checker
    bans.
    """

    reason = "fault"

    def __init__(self, message: str, slots: Sequence[int] = ()):
        super().__init__(message)
        self.slots: Tuple[int, ...] = tuple(slots)


class InjectedStepError(ServeFault):
    """Injected whole-step failure: raised before any state commit."""

    reason = FAULT_STEP_ERROR


class NonFiniteLogitsError(ServeFault):
    """Non-finite logits detected (injected or organic) on named slots."""

    reason = FAULT_NONFINITE


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``step`` is the supervisor step index it fires at.  ``slot`` is a
    victim *hint* — the engine resolves it against the slots actually
    active that step (``hint % n_active``) so seeded schedules stay
    meaningful whatever the admission pattern; ``None`` picks the first
    active slot.  ``duration``/``blocks`` shape pool-pressure holds and
    ``delay_s`` shapes slow steps; the other kinds ignore them.
    """

    step: int
    kind: str
    slot: Optional[int] = None
    duration: int = 1       # pool_pressure: steps the blocks stay held
    blocks: int = 0         # pool_pressure: blocks to grab (0 = all free)
    delay_s: float = 0.0    # slow_step: seconds the clock jumps forward

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.step < 0 or self.duration < 1 or self.delay_s < 0:
            raise ValueError(f"invalid FaultSpec timing: {self}")


class ServeFaultInjector:
    """A replayable schedule of :class:`FaultSpec`, popped step by step.

    Construct with an explicit schedule, or use :meth:`periodic` for a
    seeded pseudo-random one.  ``fired`` keeps everything already
    delivered, so a test (or ``launch/serve.py``'s summary) can report
    exactly which faults a run saw.
    """

    def __init__(self, schedule: Sequence[FaultSpec] = ()):
        self.schedule: Tuple[FaultSpec, ...] = tuple(
            sorted(schedule, key=lambda s: s.step))
        self._by_step: Dict[int, List[FaultSpec]] = {}
        for spec in self.schedule:
            self._by_step.setdefault(spec.step, []).append(spec)
        self.fired: List[FaultSpec] = []

    def pop(self, step: int) -> List[FaultSpec]:
        """Specs due at ``step`` (each delivered exactly once)."""
        specs = self._by_step.pop(step, [])
        self.fired.extend(specs)
        return specs

    @property
    def n_pending(self) -> int:
        return sum(len(v) for v in self._by_step.values())

    @classmethod
    def periodic(cls, n_steps: int, every: int,
                 kinds: Sequence[str] = (FAULT_STEP_ERROR, FAULT_NONFINITE),
                 seed: int = 0, duration: int = 2,
                 delay_s: float = 0.25) -> "ServeFaultInjector":
        """One fault every ``every`` steps over ``n_steps``, kind and
        victim slot drawn from an inline LCG — ``serve/`` is inside the
        trace-purity scope, so no ambient RNG (``numpy.random``/``random``)
        is available here, and the schedule is a pure function of
        ``(n_steps, every, kinds, seed)``.
        """
        if every < 1:
            raise ValueError("every must be >= 1")
        state = (seed ^ 0x9E3779B9) & 0xFFFFFFFF

        def nxt() -> int:
            nonlocal state
            state = (1664525 * state + 1013904223) & 0xFFFFFFFF
            return state >> 8

        specs = []
        for step in range(every - 1, n_steps, every):
            kind = kinds[nxt() % len(kinds)]
            specs.append(FaultSpec(step=step, kind=kind, slot=nxt() % 64,
                                   duration=duration, delay_s=delay_s))
        return cls(specs)
