"""Radix-tree prefix cache over paged KV blocks.

Interns finished prompt prefixes at **block granularity**: each tree edge
is one block's worth of tokens (``block_size`` positions) keyed by the
raw token bytes, and each node owns exactly one physical block id whose
KV content is the deterministic function of the token path from the root.
A new request walks the tree with its prompt, reuses every matched
block's KV verbatim (zero recompute, zero modeled ASTRA energy), and
prefills only the unmatched suffix.

Block alignment is what makes sharing safe: a shared block is never
written (divergence inside a block means that block simply isn't matched,
so the diverging request gets a private block — copy-on-write without the
copy).  Only *fully prompt-covered* blocks are interned; the partial tail
block and generated tokens stay private to the slot.

Eviction is LRU over **leaves** whose block no live slot holds
(``pool.ref == 1`` — the tree's own reference): evicting inner nodes
first would orphan children whose KV is only valid under their full
prefix path.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional

import numpy as np

from repro.serve.kv_pool import KVBlockPool


class _Node:
    __slots__ = ("children", "parent", "key", "block", "last_use")

    def __init__(self, parent: Optional["_Node"], key: bytes, block: int):
        self.children: Dict[bytes, _Node] = {}
        self.parent = parent
        self.key = key
        self.block = block
        self.last_use = 0


class RadixPrefixTree:
    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = _Node(None, b"", -1)
        self._clock = 0
        self.n_nodes = 0
        # counters surfaced by the engine / benchmarks
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.evictions = 0

    # ------------------------------------------------------------- keying
    def _chunks(self, tokens: np.ndarray, max_blocks: int) -> List[bytes]:
        """Token array ``[S]`` (or ``[C, S]`` multi-codebook) -> per-block
        byte keys for the first ``max_blocks`` fully covered blocks."""
        tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
        s = tokens.shape[-1]
        n = min(s // self.block_size, max_blocks)
        return [
            np.ascontiguousarray(
                tokens[..., j * self.block_size:(j + 1) * self.block_size]
            ).tobytes()
            for j in range(n)
        ]

    # ------------------------------------------------------------ matching
    def match(self, tokens: np.ndarray, max_blocks: int) -> List[int]:
        """Longest interned block-aligned prefix of ``tokens``.

        Returns the matched physical block ids in order (possibly empty)
        and touches each node's LRU clock.  The caller must ``incref``
        every returned block before anything else can trigger eviction.
        """
        chunks = self._chunks(tokens, max_blocks)
        if not chunks:
            return []  # prompt too short to consult the tree: not a miss
        self._clock += 1
        node = self.root
        blocks: List[int] = []
        for key in chunks:
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = self._clock
            blocks.append(child.block)
            node = child
        if blocks:
            self.hits += 1
            self.hit_tokens += len(blocks) * self.block_size
        else:
            self.misses += 1
        return blocks

    # ------------------------------------------------------------- intern
    def insert(self, tokens: np.ndarray, blocks: List[int], pool: KVBlockPool) -> int:
        """Intern ``tokens``' fully covered prompt blocks, adopting ids
        from ``blocks`` (the owning slot's table, same order).

        Already-interned prefixes keep their existing block (the caller's
        duplicate stays slot-owned and is freed at retire); each newly
        adopted block gets one tree-held reference.  Returns the number of
        blocks adopted.
        """
        self._clock += 1
        node = self.root
        adopted = 0
        for key, block in zip(self._chunks(tokens, len(blocks)), blocks):
            child = node.children.get(key)
            if child is None:
                if block == 0:
                    break  # never intern the scratch sink
                child = _Node(node, key, block)
                node.children[key] = child
                pool.incref(block)
                self.n_nodes += 1
                adopted += 1
            child.last_use = self._clock
            node = child
        return adopted

    # ------------------------------------------------------------ eviction
    def _evictable_leaves(self, pool: KVBlockPool) -> List[_Node]:
        out = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root and not n.children and pool.ref(n.block) == 1:
                out.append(n)
        return out

    def evict(self, n_needed: int, pool: KVBlockPool) -> int:
        """Free at least ``n_needed`` blocks by dropping LRU unreferenced
        leaves.  One tree scan seeds the candidate heap; a parent joins it
        when its last child is evicted (pool refs only change through our
        own decrefs here, so incremental maintenance is exact).  Returns
        how many blocks were actually freed."""
        heap = [(n.last_use, i, n) for i, n in enumerate(self._evictable_leaves(pool))]
        heapq.heapify(heap)
        tiebreak = len(heap)
        freed = 0
        while freed < n_needed and heap:
            _, _, victim = heapq.heappop(heap)
            parent = victim.parent
            del parent.children[victim.key]
            pool.decref(victim.block)  # tree-held ref -> 0 -> free list
            self.n_nodes -= 1
            self.evictions += 1
            freed += 1
            if (parent is not self.root and not parent.children
                    and pool.ref(parent.block) == 1):
                heapq.heappush(heap, (parent.last_use, tiebreak, parent))
                tiebreak += 1
        return freed

    def interned_blocks(self) -> List[int]:
        """Every block id the tree currently holds a reference on.

        One entry per node (the tree holds exactly one ref per interned
        block) — this is the tree's leg of ``ServeEngine.audit()``'s
        refcount cross-check.
        """
        out: List[int] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root:
                out.append(n.block)
        return out

    def __len__(self) -> int:
        return self.n_nodes
