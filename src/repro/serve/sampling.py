"""Token sampling for the serve engine: greedy, temperature, top-k.

``SamplerConfig`` is a frozen (hashable) dataclass so it can ride through
``jax.jit`` as a static argument — the whole fused decode loop specializes
on the sampling strategy and compiles it into the scan body.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """temperature == 0 -> greedy argmax; top_k == 0 -> full distribution."""

    temperature: float = 0.0
    top_k: int = 0

    def __post_init__(self):
        assert self.temperature >= 0.0, self.temperature
        assert self.top_k >= 0, self.top_k


GREEDY = SamplerConfig()


def sample_logits(logits: jax.Array, sampler: SamplerConfig, key) -> jax.Array:
    """Sample token ids from ``logits [..., V]`` -> ids ``[...]`` int32."""
    if sampler.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / sampler.temperature
    if sampler.top_k > 0 and sampler.top_k < lg.shape[-1]:
        kth = jax.lax.top_k(lg, sampler.top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def sample_next_token(logits: jax.Array, sampler: SamplerConfig, key,
                      cfg: ArchConfig) -> jax.Array:
    """Last-position logits -> the next input token, decode-shaped.

    logits [B, S, V] (or [B, S, C, V] multi-codebook): takes position -1 and
    returns [B, 1] (or [B, C, 1]) — exactly what ``Model.decode`` ingests.
    """
    ids = sample_logits(logits[:, -1], sampler, key)  # [B] or [B, C]
    if cfg.n_codebooks:
        return ids[..., None]  # [B, C, 1]
    return ids[:, None]  # [B, 1]
