"""Open-loop serving front-end: admission control + per-token streaming.

``ServeEngine`` is a closed-loop batch machine — callers ``submit()`` and
``run()`` to completion, and nothing ever says "no".  Production traffic
is open-loop: requests arrive on their own schedule, capacity is finite,
and an overloaded server must shed load *visibly* instead of queueing
without bound.  :class:`ServeFrontend` wraps one engine with exactly that
policy surface (docs/SERVING.md §Traffic, SLOs, and backpressure):

* **admission queue** — a bounded FCFS waiting line in front of the
  engine.  ``max_queue_depth`` caps it (a full queue rejects new arrivals
  immediately); ``queue_timeout_s`` rejects requests that wait too long;
  ``max_concurrency`` caps how many admitted requests may be in flight in
  the engine at once.  Every rejection produces a terminal
  :class:`~repro.serve.engine.RequestOutput` with ``reject_reason`` set
  ("queue_full" | "queue_timeout") and queue-wait-only timing — rejected
  requests never silently vanish, and their waits are visible in
  ``RequestTiming``.
* **per-token streaming** — the engine's incremental drain path
  (``ServeEngine(token_sink=...)``) feeds per-request
  :class:`TokenStream` iterators and ``on_tokens`` callbacks: callers
  observe tokens as each fused chunk completes, token-identical to the
  batch ``run()`` output (EOS-trimmed at the source).  Finished
  ``RequestOutput``s still flow through ``drain()``/``run()`` exactly
  once, preserving the engine's outbox discipline.
* **injected clock** — every latency anchor (submission, queue waits,
  timeouts, deadlines, retry backoff) reads the engine's ``clock``, so
  the traffic replay harness (``repro.traffic``) can drive the whole
  stack on a virtual clock and get deterministic latency trajectories.
* **fault tolerance** (docs/SERVING.md §Fault tolerance) — per-request
  **deadlines** (waiting requests expire; in-flight requests are
  cancelled mid-decode, freeing their KV blocks), a client
  :meth:`ServeFrontend.cancel`, and capped-exponential-backoff **retry**
  for the retryable fault classes (``serve/faults.py``): a faulted
  attempt's partial stream is withdrawn and the request re-enters the
  waiting line after its backoff — same request id, original submission
  timestamp, so end-to-end latency covers every attempt.  Pass a
  :class:`~repro.serve.supervisor.EngineSupervisor` to step the engine
  through the fault-containment layer.

The front-end is sans-io and single-threaded: nothing here sleeps or
spawns; ``pump()`` advances the world one engine round, and iterators
pump on demand.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.accounting import RequestTiming
from repro.serve.engine import RequestOutput, ServeEngine
from repro.serve.faults import CANCEL_CLASS, CANCELLED, DEADLINE_EXCEEDED, RETRYABLE_FAULTS

REJECT_QUEUE_FULL = "queue_full"
REJECT_QUEUE_TIMEOUT = "queue_timeout"


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Admission + fault policy for :class:`ServeFrontend`.

    * ``max_queue_depth`` — most requests allowed to *wait* in front of
      the engine; ``0`` means no waiting room (admit-or-reject), ``None``
      means unbounded.
    * ``queue_timeout_s`` — a request waiting longer than this is
      rejected with ``reject_reason="queue_timeout"``; ``None`` waits
      forever.
    * ``max_concurrency`` — most admitted requests in flight inside the
      engine at once; ``None`` means the engine's ``max_slots``.  Must
      not exceed ``max_slots`` (the excess could only sit in the
      engine-internal queue, invisible to the timeout policy).
    * ``default_deadline_s`` — per-request end-to-end deadline measured
      from submission (``submit(deadline_s=...)`` overrides it): a
      waiting request past its deadline terminates immediately, an
      in-flight one is cancelled mid-decode (KV blocks freed), both as
      terminal ``fault_reason="deadline_exceeded"`` outputs.  ``None``
      disables deadlines.
    * ``max_retries`` — attempts *beyond the first* granted to requests
      that end in a retryable fault class (``serve/faults.py``:
      step_error / nonfinite_logits / pool_pressure).  0 disables retry.
    * ``retry_backoff_s`` — base backoff before re-admission; attempt
      ``k`` waits ``min(base * 2**(k-1), 8 * base)`` on the injected
      clock, never ambient time.
    """

    max_queue_depth: Optional[int] = None
    queue_timeout_s: Optional[float] = None
    max_concurrency: Optional[int] = None
    default_deadline_s: Optional[float] = None
    max_retries: int = 0
    retry_backoff_s: float = 0.5

    def __post_init__(self):
        if self.max_queue_depth is not None and self.max_queue_depth < 0:
            raise ValueError(
                f"max_queue_depth={self.max_queue_depth} is negative; pass "
                "a queue capacity >= 0 (0 = no waiting room) or None for "
                "unbounded"
            )
        if self.queue_timeout_s is not None and self.queue_timeout_s <= 0:
            raise ValueError(
                f"queue_timeout_s={self.queue_timeout_s} must be > 0 "
                "(None disables the timeout)"
            )
        if self.max_concurrency is not None and self.max_concurrency < 1:
            raise ValueError(
                f"max_concurrency={self.max_concurrency} must be >= 1 "
                "(None inherits the engine's max_slots)"
            )
        if self.default_deadline_s is not None and self.default_deadline_s <= 0:
            raise ValueError(
                f"default_deadline_s={self.default_deadline_s} must be > 0 "
                "(None disables deadlines)"
            )
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries={self.max_retries} is negative (0 disables "
                "retry)"
            )
        if self.retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s={self.retry_backoff_s} is negative"
            )


@dataclasses.dataclass
class _Pending:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int]
    t_enqueue: float


class TokenStream:
    """Per-token iterator over one request's generated tokens.

    Iterating yields one token at a time (a scalar array, or ``[C]`` for
    multi-codebook models) as soon as the fused chunk that produced it
    completes; ``__next__`` pumps the front-end until a token is
    available or the request finishes.  After exhaustion (or an
    up-front rejection) ``output`` holds the terminal
    :class:`RequestOutput`.  The concatenation of the yielded tokens is
    exactly ``output.tokens``.

    Retry caveat: when a faulted attempt is retried, its not-yet-consumed
    buffered tokens are withdrawn and the stream restarts from the retry
    attempt's first token — tokens a caller already pulled out cannot be
    unseen, so consume streams only if retries are off or duplicates are
    acceptable (the replay harness uses the ``on_retry`` hook to keep its
    accounting exact)."""

    def __init__(self, frontend: "ServeFrontend", request_id: int):
        self._fe = frontend
        self.request_id = request_id
        self.output: Optional[RequestOutput] = None
        self._buf: Deque[np.ndarray] = deque()

    def _push(self, toks: np.ndarray) -> None:
        for j in range(toks.shape[-1]):
            self._buf.append(np.asarray(toks[..., j]))

    @property
    def finished(self) -> bool:
        return self.output is not None

    def __iter__(self) -> "TokenStream":
        return self

    def __next__(self) -> np.ndarray:
        while not self._buf:
            if self.output is not None:
                raise StopIteration
            if not self._fe.busy():
                raise RuntimeError(
                    f"token stream for request {self.request_id} stalled: "
                    "front-end is idle but the request never finished"
                )
            self._fe.pump()
        return self._buf.popleft()


class ServeFrontend:
    """Admission-controlled, streaming wrapper around one ``ServeEngine``.

    The front-end owns the engine's request-id space
    (``engine.allocate_request_id``) and its submission timestamps:
    ``Request.t_submit`` is stamped at *front-end* admission, so queue
    waits spent under backpressure — and the waits of requests that end
    up rejected — are visible in every ``RequestTiming``.

    ``supervisor`` (optional, must wrap this same engine) routes every
    engine round through the fault-containment layer
    (:class:`~repro.serve.supervisor.EngineSupervisor`): injected faults
    fire, faulted slots quarantine, and the per-step audit runs.  Without
    one, engine faults propagate out of :meth:`pump` unhandled.
    """

    def __init__(self, engine: ServeEngine,
                 config: Optional[FrontendConfig] = None,
                 clock: Optional[Callable[[], float]] = None,
                 supervisor=None):
        # None sentinel for the same reason as ServeEngine: never share a
        # module-level default instance across front-ends
        config = FrontendConfig() if config is None else config
        if config.max_concurrency is not None \
                and config.max_concurrency > engine.config.max_slots:
            raise ValueError(
                f"max_concurrency={config.max_concurrency} exceeds the "
                f"engine's max_slots={engine.config.max_slots}: the excess "
                "would wait in the engine-internal queue, outside the "
                "queue-timeout policy"
            )
        if supervisor is not None and supervisor.engine is not engine:
            raise ValueError(
                "supervisor wraps a different engine than this front-end; "
                "fault containment and admission must act on one engine"
            )
        self.engine = engine
        self.config = config
        self.clock = clock or engine.clock
        self.supervisor = supervisor
        self._stepper = engine.step if supervisor is None else supervisor.step
        self._max_inflight = config.max_concurrency or engine.config.max_slots
        self._waiting: Deque[_Pending] = deque()
        self._inflight: set = set()
        # full request records for everything forwarded (retry needs them)
        self._inflight_info: Dict[int, _Pending] = {}
        self._deadlines: Dict[int, float] = {}  # rid -> absolute deadline
        self._retry_wait: List[Tuple[float, _Pending]] = []  # (ready_at, p)
        self._attempts: Dict[int, int] = {}  # rid -> retries consumed
        self._outbox: List[RequestOutput] = []
        self._streams: Dict[int, TokenStream] = {}
        self._callbacks: Dict[int, Callable[[np.ndarray], None]] = {}
        # fired with the request id whenever a faulted attempt is retried
        # (the replay harness resets its per-request token accounting here)
        self.on_retry: Optional[Callable[[int], None]] = None
        # counters surfaced as `.stats` (benchmarks/traffic.py reports them)
        self._n_submitted = 0
        self._n_completed = 0
        self._n_rejected = {REJECT_QUEUE_FULL: 0, REJECT_QUEUE_TIMEOUT: 0}
        self._n_faulted = 0
        self._n_cancelled = 0
        self._n_retries = 0
        self._hw_queue_depth = 0  # high-water mark of the waiting line
        # incremental drain: route engine token chunks to streams/callbacks
        # (chain, so an externally installed sink keeps working)
        self._prev_sink = engine.token_sink
        engine.token_sink = self._route_tokens

    # ------------------------------------------------------------ intake
    def submit(self, prompt, max_new_tokens: int, eos_id: Optional[int] = None,
               on_tokens: Optional[Callable[[np.ndarray], None]] = None,
               deadline_s: Optional[float] = None) -> int:
        """Admit (or reject) one request; returns its request id.

        ``on_tokens`` (optional) is called with each freshly generated
        token chunk (``[k]`` or ``[C, k]``) as it completes — the callback
        flavour of :meth:`stream`.  ``deadline_s`` (optional) overrides
        ``config.default_deadline_s`` for this request.  Rejection is
        immediate only for a full queue; queue timeouts surface from a
        later ``pump()``.  Either way the terminal output arrives through
        ``drain()``/``run()``.
        """
        prompt = self.engine.check_request(prompt, max_new_tokens)
        rid = self.engine.allocate_request_id()
        if on_tokens is not None:
            self._callbacks[rid] = on_tokens
        self._admit(rid, prompt, max_new_tokens, eos_id, deadline_s)
        return rid

    def stream(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> TokenStream:
        """Admit one request and return its per-token iterator.

        A request rejected at admission returns an already-finished
        stream (``output.reject_reason`` set, zero tokens)."""
        prompt = self.engine.check_request(prompt, max_new_tokens)
        rid = self.engine.allocate_request_id()
        # register before admitting: a gen_len==0 or instantly-rejected
        # request finishes inside _admit
        s = TokenStream(self, rid)
        self._streams[rid] = s
        self._admit(rid, prompt, max_new_tokens, eos_id, deadline_s)
        return s

    def _admit(self, rid: int, prompt: np.ndarray, max_new_tokens: int,
               eos_id: Optional[int],
               deadline_s: Optional[float] = None) -> None:
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s={deadline_s} must be > 0")
        now = self.clock()
        self._n_submitted += 1
        deadline = (deadline_s if deadline_s is not None
                    else self.config.default_deadline_s)
        if deadline is not None:
            self._deadlines[rid] = now + deadline
        self._expire(now)
        self._waiting.append(_Pending(rid, prompt, max_new_tokens, eos_id, now))
        self._forward(now)
        if (self.config.max_queue_depth is not None
                and len(self._waiting) > self.config.max_queue_depth):
            # the newest arrival is the overflow: everyone ahead was within
            # bound when they were admitted (invariant: depth <= max before
            # every append)
            p = self._waiting.pop()
            self._reject(p.rid, p.prompt, now, now, REJECT_QUEUE_FULL)
        else:
            self._hw_queue_depth = max(self._hw_queue_depth, len(self._waiting))

    # ------------------------------------------------------------- engine
    def busy(self) -> bool:
        return bool(self._waiting or self._inflight or self._retry_wait)

    def pump(self) -> None:
        """One scheduling round: expire timed-out waiters, enforce
        deadlines, re-admit retries whose backoff elapsed, forward into
        the engine up to ``max_concurrency``, run one engine round
        (through the supervisor when present), route finished outputs.
        Outputs accumulate for ``drain()``."""
        now = self.clock()
        self._expire(now)
        self._check_deadlines(now)
        now = self._revive_retries(now)
        self._forward(now)
        if self.engine.has_work() or self._inflight:
            for out in self._stepper():
                self._finish(out)

    def drain(self) -> List[RequestOutput]:
        """Hand over every output finished since the last collection —
        served and rejected alike — exactly once."""
        outs, self._outbox = self._outbox, []
        return outs

    def run(self) -> List[RequestOutput]:
        """Pump until idle; returns all pending outputs in id order."""
        outs = self.drain()
        while self.busy():
            self.pump()
            outs.extend(self.drain())
        return sorted(outs, key=lambda o: o.request_id)

    # -------------------------------------------------------------- faults
    def cancel(self, request_id: int) -> bool:
        """Client cancellation: terminate a waiting, backing-off, or
        in-flight request.  Mid-decode cancellation frees the request's
        KV blocks immediately (``engine.cancel`` → quarantine); the
        terminal ``fault_reason="cancelled"`` output arrives through
        ``drain()``.  Returns False for unknown/finished ids."""
        now = self.clock()
        for j, p in enumerate(self._waiting):
            if p.rid == request_id:
                del self._waiting[j]
                self._fault_terminal(p, CANCELLED, now)
                return True
        for j, (_t, p) in enumerate(self._retry_wait):
            if p.rid == request_id:
                del self._retry_wait[j]
                self._fault_terminal(p, CANCELLED, now)
                return True
        if request_id in self._inflight:
            # output flows back through the engine outbox on the next pump
            return self.engine.cancel(request_id, CANCELLED)
        return False

    def _check_deadlines(self, now: float) -> None:
        for rid, deadline in list(self._deadlines.items()):
            if now < deadline:
                continue
            handled = False
            for j, p in enumerate(self._waiting):
                if p.rid == rid:
                    del self._waiting[j]
                    self._fault_terminal(p, DEADLINE_EXCEEDED, now)
                    handled = True
                    break
            if not handled:
                for j, (_t, p) in enumerate(self._retry_wait):
                    if p.rid == rid:
                        del self._retry_wait[j]
                        self._fault_terminal(p, DEADLINE_EXCEEDED, now)
                        handled = True
                        break
            if not handled and rid in self._inflight:
                self.engine.cancel(rid, DEADLINE_EXCEEDED)
                self._deadlines.pop(rid, None)

    def _revive_retries(self, now: float) -> float:
        """Move retries whose backoff elapsed to the *front* of the
        waiting line (they already waited a full queue pass).  When
        future retries are the only remaining work, advance an
        advanceable (virtual) clock to the earliest ready time so
        ``run()`` terminates deterministically instead of spinning."""
        if not self._retry_wait:
            return now
        if (not self._waiting and not self._inflight
                and not self.engine.has_work()):
            t_next = min(t for t, _ in self._retry_wait)
            advance = getattr(self.clock, "advance", None)
            if t_next > now and advance is not None:
                advance(t_next - now)
                now = self.clock()
        ready = sorted([e for e in self._retry_wait if e[0] <= now],
                       key=lambda e: e[0], reverse=True)
        if ready:
            self._retry_wait = [e for e in self._retry_wait if e[0] > now]
            for _t, p in ready:
                self._waiting.appendleft(p)
        return now

    def _fault_terminal(self, p: _Pending, reason: str, now: float) -> None:
        """Terminal fault output for a request that never (re)reached the
        engine: queue-wait-only timing, like a rejection."""
        wait = max(now - p.t_enqueue, 0.0)
        timing = RequestTiming(queue_time_s=wait, ttft_s=0.0, wall_time_s=wait,
                               mean_itl_s=0.0, max_itl_s=0.0, n_token_events=0)
        shape = (p.prompt.shape[0], 0) if p.prompt.ndim == 2 else (0,)
        out = RequestOutput(p.rid, p.prompt, np.zeros(shape, np.int32),
                            wall_time_s=wait, hardware=None, timing=timing,
                            fault_reason=reason)
        self._finish(out)

    # ------------------------------------------------------------ internals
    def _expire(self, now: float) -> None:
        timeout = self.config.queue_timeout_s
        if timeout is None:
            return
        # t_enqueue is nondecreasing along the FCFS deque, so expired
        # requests are always a prefix... except revived retries, whose
        # enqueue times are older still — also a prefix, so still correct
        while self._waiting and now - self._waiting[0].t_enqueue >= timeout:
            p = self._waiting.popleft()
            self._reject(p.rid, p.prompt, p.t_enqueue, now, REJECT_QUEUE_TIMEOUT)

    def _forward(self, now: float) -> None:
        forwarded = False
        while self._waiting and len(self._inflight) < self._max_inflight:
            p = self._waiting.popleft()
            self._inflight.add(p.rid)
            self._inflight_info[p.rid] = p
            self.engine.submit(p.prompt, p.max_new_tokens, p.eos_id,
                               request_id=p.rid, t_submit=p.t_enqueue)
            forwarded = True
        if forwarded:
            # max_new_tokens==0 requests complete synchronously inside
            # engine.submit; collect them now so their streams finish at
            # admission rather than on the next pump
            for out in self.engine._drain():
                self._finish(out)

    def _route_tokens(self, rid: int, toks: np.ndarray) -> None:
        if self._prev_sink is not None:
            self._prev_sink(rid, toks)
        cb = self._callbacks.get(rid)
        if cb is not None:
            cb(toks)
        s = self._streams.get(rid)
        if s is not None:
            s._push(toks)

    def _finish(self, out: RequestOutput) -> None:
        rid = out.request_id
        p = self._inflight_info.pop(rid, None)
        self._inflight.discard(rid)
        if (out.fault_reason in RETRYABLE_FAULTS and p is not None
                and self._attempts.get(rid, 0) < self.config.max_retries):
            # retry instead of terminal delivery: same rid, original
            # submission time, capped exponential backoff on the injected
            # clock.  The faulted attempt's partial stream is withdrawn.
            attempt = self._attempts[rid] = self._attempts.get(rid, 0) + 1
            self._n_retries += 1
            base = self.config.retry_backoff_s
            delay = min(base * (2 ** (attempt - 1)), 8 * base)
            self._retry_wait.append((self.clock() + delay, p))
            s = self._streams.get(rid)
            if s is not None:
                s._buf.clear()
            if self.on_retry is not None:
                self.on_retry(rid)
            return
        self._deadlines.pop(rid, None)
        self._attempts.pop(rid, None)
        if out.reject_reason is not None:
            pass  # counted at the _reject site
        elif out.fault_reason is None:
            self._n_completed += 1
        elif out.fault_reason in CANCEL_CLASS:
            self._n_cancelled += 1
        else:
            self._n_faulted += 1
        self._outbox.append(out)
        self._callbacks.pop(rid, None)
        s = self._streams.pop(rid, None)
        if s is not None:
            s.output = out

    def _reject(self, rid: int, prompt: np.ndarray, t_submit: float,
                now: float, reason: str) -> None:
        wait = max(now - t_submit, 0.0)
        timing = RequestTiming(queue_time_s=wait, ttft_s=0.0, wall_time_s=wait,
                               mean_itl_s=0.0, max_itl_s=0.0, n_token_events=0)
        shape = (prompt.shape[0], 0) if prompt.ndim == 2 else (0,)
        out = RequestOutput(rid, prompt, np.zeros(shape, np.int32),
                            wall_time_s=wait, hardware=None, timing=timing,
                            reject_reason=reason)
        self._n_rejected[reason] += 1
        self._finish(out)

    # -------------------------------------------------------------- stats
    @property
    def stats(self) -> Dict[str, int]:
        """Admission + fault counters.  Conservation invariant
        (tests/test_faults.py): ``submitted == completed + rejected_* +
        faulted + cancelled + queue_depth + in_flight + retry_pending``
        at every quiescent point."""
        return {
            "submitted": self._n_submitted,
            "completed": self._n_completed,
            "rejected_queue_full": self._n_rejected[REJECT_QUEUE_FULL],
            "rejected_queue_timeout": self._n_rejected[REJECT_QUEUE_TIMEOUT],
            "faulted": self._n_faulted,
            "cancelled": self._n_cancelled,
            "retries": self._n_retries,
            "retry_pending": len(self._retry_wait),
            "max_queue_depth": self._hw_queue_depth,
            "queue_depth": len(self._waiting),
            "in_flight": len(self._inflight),
        }
