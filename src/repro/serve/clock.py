"""The one sanctioned wall-clock entry point for the serving stack.

Everything else under ``src/repro/serve`` is trace-pure by lint
(``repro.analysis`` §trace-purity): the engine, scheduler, and frontend
read time only through an injected ``clock`` callable so the traffic
harness can replay whole serving runs on a virtual clock and get
bit-identical outputs.  ``ServeEngine(clock=None)`` falls back to
:data:`wall_clock` — *this* module is where that ambient read lives, and
it lives nowhere else.
"""
from __future__ import annotations

import time
from typing import Callable

# live serving default; replayed runs inject a virtual clock instead
wall_clock: Callable[[], float] = time.time  # repro-lint: disable=trace-purity -- the single sanctioned ambient-clock read; engines default to it only when no clock is injected


def resolve_clock(clock: Callable[[], float] | None) -> Callable[[], float]:
    """Injected clock if given, else the ambient wall clock."""
    return clock if clock is not None else wall_clock
