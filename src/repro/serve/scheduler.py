"""Token-budget scheduler: chunked prefill with decode priority.

The blocking admission path runs a *full-prompt* prefill before any
decode chunk can dispatch, so one long prompt stalls every active slot's
token stream (head-of-line blocking).  This module is the policy side of
the fix (Sarathi/vLLM-style chunked prefill): prompt prefill is split
into bounded chunks interleaved with decode chunks, so in-flight decode
is never stalled for more than one bounded dispatch.

Each engine *round* is one ``ServeEngine.step()``:

  admit  -> waiting requests claim free slots in FCFS order and enter the
            ``PREFILLING`` state (no prefill work yet);
  prefill-> at most one bounded dispatch covering this round's prefill
            chunk assignments (this module decides them);
  decode -> one fused chunk over the ``DECODING`` slots (always runs —
            decode has structural priority, prefill can never displace it).

The per-round *token budget* is shared between the two phases: decode
claims one token per active slot (each fused step advances every active
slot by one position), and prefill gets the remainder,

    prefill_budget = max(token_budget - n_active_decode, 0)

split across the PREFILLING slots oldest-first (FCFS — a later prompt
only gets budget once every earlier prompt's remaining need is covered
this round).  When decode occupies the whole budget, prefill waits;
slots retiring frees budget, so admission is delayed, never deadlocked.
Chunk widths are bucketed to powers of two so the number of distinct
compiled prefill programs stays logarithmic in the budget.

Metric definitions used by the engine/benchmarks (docs/SERVING.md):

* ``queue_time_s`` — submit -> admission into a slot;
* ``TTFT`` — submit -> first generated token on the host;
* ``ITL`` — gap between consecutive token-arrival events of one request
  (a fused chunk delivers its tokens as one event; ``max_itl_s`` is the
  worst such gap, the quantity head-of-line blocking inflates).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


def pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= ``n``, clamped to ``cap``.

    Bounds the set of compiled chunk widths: every dispatch is padded to
    a bucket, so at most ``log2(cap)`` distinct programs exist per model.
    """
    if n <= 0:
        return 0
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Policy knobs for :class:`TokenBudgetScheduler`.

    ``token_budget`` is the per-round cap shared by decode (priority) and
    prefill — the CLI exposes it as ``--prefill-chunk-tokens``.  A budget
    at or below the live decode count starves prefill until slots retire;
    that is a throughput/latency trade the operator opted into, not an
    error, but budgets comfortably above ``max_slots`` are the useful
    regime.
    """

    token_budget: int

    def __post_init__(self):
        if self.token_budget < 1:
            raise ValueError(
                f"token_budget must be >= 1, got {self.token_budget} "
                "(0 selects the blocking admission path at the engine level)"
            )


class TokenBudgetScheduler:
    """FCFS chunked-prefill planner with decode priority.

    Pure host-side policy: the engine owns the queue and the slots; this
    object decides how many prompt tokens each PREFILLING slot may run
    this round, and keeps the counters surfaced as
    ``ServeEngine.scheduler_stats``.
    """

    def __init__(self, config: SchedulerConfig):
        self.config = config
        # counters surfaced by the engine / benchmarks
        self.rounds = 0
        self.chunks = 0
        self.prefill_tokens = 0
        self.starved_rounds = 0  # rounds where decode consumed the budget

    def prefill_budget(self, n_active_decode: int) -> int:
        """Tokens left for prefill after decode's per-round claim."""
        return max(self.config.token_budget - n_active_decode, 0)

    def plan_chunks(self, needs: Sequence[Tuple[int, int]],
                    n_active_decode: int) -> List[Tuple[int, int]]:
        """Assign this round's prefill budget FCFS.

        ``needs`` is ``[(slot_id, remaining_prompt_tokens)]`` in admission
        order; returns ``[(slot_id, chunk_len)]`` for the slots that get
        work this round (possibly empty).  The head request is served
        first and fully before any budget reaches the next one.
        """
        if not needs:
            return []
        self.rounds += 1
        budget = self.prefill_budget(n_active_decode)
        if budget == 0:
            self.starved_rounds += 1
            return []
        plan: List[Tuple[int, int]] = []
        for slot_id, need in needs:
            if budget <= 0:
                break
            take = min(need, budget)
            if take > 0:
                plan.append((slot_id, take))
                budget -= take
        self.chunks += len(plan)
        self.prefill_tokens += sum(t for _, t in plan)
        return plan

    @property
    def stats(self) -> Dict[str, int]:
        return {
            "token_budget": self.config.token_budget,
            "rounds": self.rounds,
            "prefill_chunks": self.chunks,
            "prefill_tokens": self.prefill_tokens,
            "starved_rounds": self.starved_rounds,
        }


class DegradedLadder:
    """Pool-pressure response ladder (docs/SERVING.md §Fault tolerance).

    The paged engine's construction-time block floor makes *organic*
    admission infallible, so a stalled admission round means external
    pressure: held blocks (a co-tenant, an injected ``pool_pressure``
    fault) or a broken pool.  Instead of wedging, the engine walks this
    ladder one level per stalled round, trading cache value for
    admission headroom:

      ``normal`` -> ``flush_prefix``        (evict every evictable
                                             interned prefix block)
           -> ``no_prefix_admission``       (stop matching/interning
                                             prefixes entirely, flush
                                             again each stalled round)
           -> ``shed_load``                 (fail the queue head as a
                                             terminal ``pool_pressure``
                                             fault output — bounded: one
                                             request per stalled round)

    Each round with admission progress relaxes one level; back at
    ``normal`` the engine re-enables prefix admission.  Every transition
    is recorded as ``(engine_step, new_level)`` and surfaced through
    ``ServeEngine.stats()`` / ``kv_stats``, so degraded operation is
    observable, never silent.  Pure host-side policy, like the
    scheduler: the engine owns all the acting.
    """

    NORMAL, FLUSH_PREFIX, NO_PREFIX_ADMISSION, SHED_LOAD = range(4)
    LEVEL_NAMES = ("normal", "flush_prefix", "no_prefix_admission",
                   "shed_load")

    def __init__(self):
        self.level = self.NORMAL
        self.transitions: List[Tuple[int, str]] = []

    @property
    def level_name(self) -> str:
        return self.LEVEL_NAMES[self.level]

    def escalate(self, step: int) -> int:
        """One stalled admission round: move one level up (saturating)."""
        if self.level < self.SHED_LOAD:
            self.level += 1
            self.transitions.append((step, self.level_name))
        return self.level

    def relax(self, step: int) -> int:
        """One round with admission progress: move one level down."""
        if self.level > self.NORMAL:
            self.level -= 1
            self.transitions.append((step, self.level_name))
        return self.level
