"""Host-side block allocator for the paged KV cache.

The serve engine's paged mode stores attention KV in fixed-size *blocks*
(``block_size`` token positions each) drawn from one global pool per
attention layer.  All layers share a single **block-id space**: a slot's
block table (``[W]`` physical ids, ``W = ceil(max_len / block_size)``)
indexes every layer's pool tensor at once, vLLM-style.  This module is
the host-side bookkeeping only — the device tensors live inside the
engine's state pytree (:class:`repro.models.attention.PagedKVCache`).

Invariants (docs/SERVING.md has the full memory model):

* **Block 0 is the scratch sink.**  It is never allocated, never
  interned, and never read at a maskable position — table entries beyond
  a slot's allocated region point at it, so padded/overrun writes from
  packed prefill land somewhere harmless instead of corrupting a
  neighbour's blocks.
* **Ref-counted sharing.**  A block's refcount is (#slots holding it in
  their table) + (1 if the radix prefix tree has interned it).  Blocks
  return to the free list only at refcount zero; double-free raises.
* **Immutable when shared.**  The engine only ever writes a block it
  allocated for the writing slot (prefix matching is block-aligned, so
  the diverging block is always private) — copy-on-write reduces to
  "divergence allocates, never mutates".
"""
from __future__ import annotations

from typing import List


class KVBlockPool:
    """Free-list + refcount allocator over ``n_blocks`` physical blocks."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError(f"pool needs >= 2 blocks (1 scratch + 1 usable), got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        # storage bytes per physical block summed across every layer's K+V
        # pool tensors, at their actual dtype (int8 under kv_quant).  The
        # engine stamps this after building the device pools; it is the
        # unit of all serve-side KV byte accounting (docs/SERVING.md §KV
        # quantization).
        self.bytes_per_block = 0
        # block 0 reserved as the scratch sink; pop() hands out low ids first
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._ref = [0] * n_blocks

    # ------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Blocks currently held (excludes scratch and the free list)."""
        return self.n_blocks - 1 - len(self._free)

    def ref(self, block: int) -> int:
        return self._ref[block]

    @property
    def total_bytes(self) -> int:
        """Allocatable pool storage (scratch block 0 excluded)."""
        return (self.n_blocks - 1) * self.bytes_per_block

    @property
    def live_bytes(self) -> int:
        """Storage held by live blocks (refcount > 0)."""
        return self.n_live * self.bytes_per_block

    # ------------------------------------------------------------ lifetime
    def alloc(self, n: int) -> List[int]:
        """Hand out ``n`` fresh blocks at refcount 1.

        Raises ``RuntimeError`` on exhaustion — the engine sizes the pool
        so that (after evicting every tree-only block) admission can never
        hit this; see ``ServeEngine``'s construction-time assertion.
        """
        if n > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: need {n} blocks, {len(self._free)} free "
                f"of {self.n_blocks - 1} allocatable"
            )
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._ref[b] = 1
        return out

    def incref(self, block: int) -> None:
        if block == 0:
            raise ValueError("scratch block 0 is not ref-counted")
        if self._ref[block] <= 0:
            raise ValueError(f"incref on free block {block}")
        self._ref[block] += 1

    def decref(self, block: int) -> None:
        """Drop one reference; a block at zero returns to the free list."""
        if block == 0:
            raise ValueError("scratch block 0 is not ref-counted")
        if self._ref[block] <= 0:
            raise ValueError(f"double free of block {block}")
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)

    # --------------------------------------------------------------- audit
    def check_consistent(self) -> None:
        """Free-list/refcount cross-check (``ServeEngine.audit()`` leg).

        The free list must be duplicate-free, scratch-free, and must
        contain *exactly* the zero-refcount non-scratch blocks — a block
        in both worlds (free yet referenced) or in neither (leaked) is a
        bug in release/quarantine bookkeeping.  Raises ``RuntimeError``.
        """
        free = set(self._free)
        if len(free) != len(self._free):
            raise RuntimeError("KV pool corrupt: duplicate blocks in free list")
        if 0 in free:
            raise RuntimeError("KV pool corrupt: scratch block 0 on free list")
        zero_ref = {b for b in range(1, self.n_blocks) if self._ref[b] == 0}
        if free != zero_ref:
            leaked = sorted(zero_ref - free)
            phantom = sorted(free - zero_ref)
            raise RuntimeError(
                "KV pool corrupt: free list != zero-ref blocks "
                f"(leaked={leaked[:8]}, free-but-referenced={phantom[:8]})"
            )
