"""Continuous-batching serving subsystem (DESIGN.md §Serving engine).

Public surface:

* :class:`~repro.serve.engine.ServeEngine` — request queue + slotted state
  + fused chunked decode + per-request ASTRA accounting (energy attributed
  per GEMM site).  ``ServeEngine(..., plan=...)`` serves under any
  per-site :class:`~repro.core.plan.ExecutionPlan`.
* :func:`~repro.serve.decode_loop.make_fused_decode` /
  :func:`~repro.serve.decode_loop.unfused_decode` — the scan-fused decode
  loop and its per-dispatch oracle.
* :func:`~repro.serve.prefill.pack_prompts` /
  :func:`~repro.serve.prefill.packed_prefill` — mixed-length prefill packing.
* :class:`~repro.serve.sampling.SamplerConfig` — greedy / temperature / top-k.
* :class:`~repro.serve.kv_pool.KVBlockPool` /
  :class:`~repro.serve.prefix_tree.RadixPrefixTree` — the paged-KV block
  allocator and the radix-tree prefix cache behind
  ``ServeConfig(kv_block_size=...)`` (docs/SERVING.md).
"""
from repro.serve.decode_loop import make_fused_decode, unfused_decode
from repro.serve.engine import Request, RequestOutput, ServeConfig, ServeEngine
from repro.serve.kv_pool import KVBlockPool
from repro.serve.prefill import (
    full_seq_packable, pack_prompts, packed_prefill, prefill_paged_suffix,
)
from repro.serve.prefix_tree import RadixPrefixTree
from repro.serve.sampling import GREEDY, SamplerConfig

__all__ = [
    "GREEDY",
    "KVBlockPool",
    "RadixPrefixTree",
    "Request",
    "RequestOutput",
    "SamplerConfig",
    "ServeConfig",
    "ServeEngine",
    "full_seq_packable",
    "make_fused_decode",
    "pack_prompts",
    "packed_prefill",
    "prefill_paged_suffix",
    "unfused_decode",
]
