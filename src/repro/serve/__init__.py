"""Continuous-batching serving subsystem (DESIGN.md §Serving engine).

Public surface:

* :class:`~repro.serve.engine.ServeEngine` — request queue + slotted state
  + fused chunked decode + per-request ASTRA accounting (energy attributed
  per GEMM site).  ``ServeEngine(..., plan=...)`` serves under any
  per-site :class:`~repro.core.plan.ExecutionPlan`.
* :func:`~repro.serve.decode_loop.make_fused_decode` /
  :func:`~repro.serve.decode_loop.unfused_decode` — the scan-fused decode
  loop and its per-dispatch oracle.
* :func:`~repro.serve.prefill.pack_prompts` /
  :func:`~repro.serve.prefill.packed_prefill` — mixed-length prefill packing.
* :class:`~repro.serve.sampling.SamplerConfig` — greedy / temperature / top-k.
* :class:`~repro.serve.kv_pool.KVBlockPool` /
  :class:`~repro.serve.prefix_tree.RadixPrefixTree` — the paged-KV block
  allocator and the radix-tree prefix cache behind
  ``ServeConfig(kv_block_size=...)`` (docs/SERVING.md).
* :class:`~repro.serve.scheduler.TokenBudgetScheduler` — the chunked-
  prefill policy behind ``ServeConfig(prefill_chunk_tokens=...)``
  (docs/SERVING.md §Scheduling): FCFS admission, decode priority, one
  bounded prefill dispatch per round.
* :class:`~repro.serve.accounting.RequestTiming` — measured queue/TTFT/
  ITL latency carried on every :class:`RequestOutput`.
* :class:`~repro.serve.frontend.ServeFrontend` /
  :class:`~repro.serve.frontend.FrontendConfig` /
  :class:`~repro.serve.frontend.TokenStream` — the open-loop front-end
  (docs/SERVING.md §Traffic, SLOs, and backpressure): bounded admission
  queue, queue-timeout / queue-full load shedding with visible
  ``reject_reason``, per-token streaming over the engine's incremental
  drain path.  Driven at load by :mod:`repro.traffic`.  Grown with
  per-request deadlines, client cancellation, and capped-backoff retry
  of retryable fault classes (docs/SERVING.md §Fault tolerance).
* :class:`~repro.serve.faults.ServeFaultInjector` /
  :class:`~repro.serve.faults.FaultSpec` /
  :class:`~repro.serve.supervisor.EngineSupervisor` — deterministic
  fault injection and the containment layer that quarantines only the
  faulted request (``RequestOutput.fault_reason``), releases its KV
  blocks, and keeps every other stream bit-identical to a fault-free
  replay, with a refcount/bytes ``audit()`` each step.
"""
from repro.serve.accounting import RequestTiming
from repro.serve.decode_loop import make_fused_decode, unfused_decode
from repro.serve.engine import Request, RequestOutput, ServeConfig, ServeEngine
from repro.serve.faults import (
    CANCELLED, DEADLINE_EXCEEDED, FAULT_KINDS, RETRYABLE_FAULTS, FaultSpec,
    InjectedStepError, NonFiniteLogitsError, ServeFault, ServeFaultInjector,
)
from repro.serve.frontend import (
    REJECT_QUEUE_FULL, REJECT_QUEUE_TIMEOUT, FrontendConfig, ServeFrontend,
    TokenStream,
)
from repro.serve.kv_pool import KVBlockPool
from repro.serve.prefill import (
    full_seq_packable, pack_prompts, packed_prefill, prefill_paged_suffix,
    prefill_window,
)
from repro.serve.prefix_tree import RadixPrefixTree
from repro.serve.sampling import GREEDY, SamplerConfig
from repro.serve.scheduler import DegradedLadder, SchedulerConfig, TokenBudgetScheduler
from repro.serve.slots import SlotState
from repro.serve.supervisor import EngineSupervisor

__all__ = [
    "CANCELLED",
    "DEADLINE_EXCEEDED",
    "DegradedLadder",
    "EngineSupervisor",
    "FAULT_KINDS",
    "FaultSpec",
    "GREEDY",
    "FrontendConfig",
    "InjectedStepError",
    "KVBlockPool",
    "NonFiniteLogitsError",
    "REJECT_QUEUE_FULL",
    "REJECT_QUEUE_TIMEOUT",
    "RETRYABLE_FAULTS",
    "RadixPrefixTree",
    "Request",
    "RequestOutput",
    "RequestTiming",
    "ServeFault",
    "ServeFaultInjector",
    "ServeFrontend",
    "TokenStream",
    "SamplerConfig",
    "SchedulerConfig",
    "ServeConfig",
    "ServeEngine",
    "SlotState",
    "TokenBudgetScheduler",
    "full_seq_packable",
    "make_fused_decode",
    "pack_prompts",
    "packed_prefill",
    "prefill_paged_suffix",
    "prefill_window",
    "unfused_decode",
]
