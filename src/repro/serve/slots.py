"""Axis-aware operations on the slotted decode-state pytree.

``init_decode_state`` stacks states in two subtrees whose batch axis
differs (see ``models.transformer``):

* ``states["units"]`` — scan-stacked pattern units, leaves ``[n_units, B, ...]``
  (batch axis 1);
* ``states["rem"]``   — unrolled remainder layers, leaves ``[B, ...]``
  (batch axis 0).

The serve engine treats the batch axis as *slots*: requests are admitted
into free slots and evicted at completion, so it needs batched select
(masked state updates during packed prefill) and scatter (installing a new
request's prefilled state into its slot) that know where the batch axis is.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def _batched_where(new, old, active: jax.Array, batch_axis: int):
    def sel(n, o):
        shape = [1] * n.ndim
        shape[batch_axis] = -1
        return jnp.where(active.reshape(shape), n, o)

    return jax.tree.map(sel, new, old)


def select_states(new: Dict[str, Any], old: Dict[str, Any], active: jax.Array):
    """Per-slot select: take ``new`` where ``active [B]`` else keep ``old``."""
    out: Dict[str, Any] = {}
    if "units" in new:
        out["units"] = _batched_where(new["units"], old["units"], active, 1)
    if "rem" in new:
        out["rem"] = _batched_where(new["rem"], old["rem"], active, 0)
    return out


def scatter_states(big: Dict[str, Any], small: Dict[str, Any], slot_ids: jax.Array):
    """Install ``small`` (batch k) into ``big`` (batch B) at ``slot_ids [k]``.

    ``.at[].set`` casts the update to the target leaf dtype, so prefill
    states (model dtype) land in the engine's cache dtype — the same cast
    the decode path applies on every KV write.
    """
    out: Dict[str, Any] = {}
    if "units" in big:
        out["units"] = jax.tree.map(
            lambda b, s: b.at[:, slot_ids].set(s.astype(b.dtype)), big["units"], small["units"]
        )
    if "rem" in big:
        out["rem"] = jax.tree.map(
            lambda b, s: b.at[slot_ids].set(s.astype(b.dtype)), big["rem"], small["rem"]
        )
    return out
