"""Axis-aware operations on the slotted decode-state pytree.

``init_decode_state`` stacks states in two subtrees whose batch axis
differs (see ``models.transformer``):

* ``states["units"]`` — scan-stacked pattern units, leaves ``[n_units, B, ...]``
  (batch axis 1);
* ``states["rem"]``   — unrolled remainder layers, leaves ``[B, ...]``
  (batch axis 0).

The serve engine treats the batch axis as *slots*: requests are admitted
into free slots and evicted at completion, so it needs batched select
(masked state updates during packed prefill) and scatter (installing a new
request's prefilled state into its slot) that know where the batch axis is.

An occupied slot is in one of two states (:class:`SlotState`): under the
chunked-prefill scheduler (``serve/scheduler.py``) a request holds its
slot while its prompt is still being prefilled chunk by chunk
(``PREFILLING``) before it joins the fused decode loop (``DECODING``);
the blocking admission path admits straight into ``DECODING``.
"""
from __future__ import annotations

import enum
from typing import Any, Dict

import jax
import jax.numpy as jnp


class SlotState(enum.Enum):
    """Lifecycle state of an occupied serve-engine slot."""

    PREFILLING = "prefilling"  # prompt chunks still being fed (scheduler mode)
    DECODING = "decoding"      # in the fused decode loop, generating tokens


def _batched_where(new, old, active: jax.Array, batch_axis: int):
    def sel(n, o):
        shape = [1] * n.ndim
        shape[batch_axis] = -1
        return jnp.where(active.reshape(shape), n, o)

    return jax.tree.map(sel, new, old)


def select_states(new: Dict[str, Any], old: Dict[str, Any], active: jax.Array):
    """Per-slot select: take ``new`` where ``active [B]`` else keep ``old``."""
    out: Dict[str, Any] = {}
    if "units" in new:
        out["units"] = _batched_where(new["units"], old["units"], active, 1)
    if "rem" in new:
        out["rem"] = _batched_where(new["rem"], old["rem"], active, 0)
    return out


def finite_mask(logits: jax.Array) -> jax.Array:
    """Per-slot finiteness of a decode-step logits tensor.

    Reduces every non-slot axis (``[B, 1, V]`` or ``[B, C, 1, V]`` →
    ``[B]`` bool): ``True`` iff all of the slot's logits are finite.  The
    fused decode loop ANDs this across a chunk's steps so NaN poisoning
    is attributed to the exact slot that produced it (serve/faults.py).
    """
    return jnp.all(jnp.isfinite(logits), axis=tuple(range(1, logits.ndim)))


def _block_scatter(pool: jax.Array, dense: jax.Array, rows: jax.Array, axis: int):
    """Scatter a dense per-request cache into pool blocks.

    ``pool`` [(U,) n_blocks, kv, bs, hd]; ``dense`` [(U,) n, kv, L, hd];
    ``rows`` [n, nb] physical block ids covering logical blocks 0..nb-1
    (entries past a slot's allocation point at scratch 0 — those writes
    collide harmlessly).  ``axis`` is the pool/batch axis (1 for scanned
    units, 0 for remainder layers).
    """
    bs = pool.shape[axis + 2]
    l = dense.shape[axis + 2]
    nb = rows.shape[1]
    pad = nb * bs - l
    widths = [(0, 0)] * dense.ndim
    widths[axis + 2] = (0, pad)
    d = jnp.pad(dense, widths)
    if axis == 1:
        u, n, kv, _, hd = d.shape
        vals = jnp.moveaxis(d.reshape(u, n, kv, nb, bs, hd), 2, 3)
        return pool.at[:, rows].set(vals.astype(pool.dtype))
    n, kv, _, hd = d.shape
    vals = jnp.moveaxis(d.reshape(n, kv, nb, bs, hd), 1, 2)
    return pool.at[rows].set(vals.astype(pool.dtype))


def _scatter_node(big, small, slot_ids: jax.Array, rows: jax.Array, axis: int):
    from repro.models.attention import (
        KVCache, PagedKVCache, QuantPagedKVCache, kv_quantize,
    )

    if isinstance(big, QuantPagedKVCache):
        # the QuantPagedKVCache check must precede the generic NamedTuple
        # branch: its 4 fields would zip-truncate against the 2-field dense
        # KVCache.  Dense prefill KV is quantized against the pool's baked
        # static scales before the scatter (the .astype inside
        # _block_scatter is then a no-op on the int8 payload).
        assert isinstance(small, KVCache)
        nb = min(rows.shape[1], -(-small.k.shape[axis + 2] // big.k.shape[axis + 2]))
        r = rows[:, :nb]
        # scanned units carry per-unit scale rows [U, kv] vs dense
        # [U, n, kv, L, hd]: insert the request axis so broadcasting aligns
        ks = big.k_scale[:, None] if axis == 1 else big.k_scale
        vs = big.v_scale[:, None] if axis == 1 else big.v_scale
        return big._replace(
            k=_block_scatter(big.k, kv_quantize(small.k, ks), r, axis),
            v=_block_scatter(big.v, kv_quantize(small.v, vs), r, axis))
    if isinstance(big, PagedKVCache):
        assert isinstance(small, KVCache)
        nb = min(rows.shape[1], -(-small.k.shape[axis + 2] // big.k.shape[axis + 2]))
        r = rows[:, :nb]
        return PagedKVCache(_block_scatter(big.k, small.k, r, axis),
                            _block_scatter(big.v, small.v, r, axis))
    if isinstance(big, dict):
        return {k: _scatter_node(big[k], small[k], slot_ids, rows, axis) for k in big}
    if isinstance(big, (list, tuple)):
        vals = [_scatter_node(b, s, slot_ids, rows, axis) for b, s in zip(big, small)]
        return type(big)(*vals) if hasattr(big, "_fields") else type(big)(vals)
    if axis == 1:
        return big.at[:, slot_ids].set(small.astype(big.dtype))
    return big.at[slot_ids].set(small.astype(big.dtype))


def paged_scatter_states(big: Dict[str, Any], small: Dict[str, Any],
                         slot_ids: jax.Array, rows: jax.Array):
    """Install dense prefilled states into the paged engine state.

    attn/local caches block-scatter into the shared pools via ``rows``
    (the admitted slots' block-table rows); every other leaf (recurrent,
    xattn, placeholders) dense-scatters at ``slot_ids`` exactly like
    :func:`scatter_states`.
    """
    out: Dict[str, Any] = {}
    if "units" in big:
        out["units"] = _scatter_node(big["units"], small["units"], slot_ids, rows, 1)
    if "rem" in big:
        out["rem"] = [_scatter_node(b, s, slot_ids, rows, 0)
                      for b, s in zip(big["rem"], small["rem"])]
    return out


def scatter_states(big: Dict[str, Any], small: Dict[str, Any], slot_ids: jax.Array):
    """Install ``small`` (batch k) into ``big`` (batch B) at ``slot_ids [k]``.

    ``.at[].set`` casts the update to the target leaf dtype, so prefill
    states (model dtype) land in the engine's cache dtype — the same cast
    the decode path applies on every KV write.
    """
    out: Dict[str, Any] = {}
    if "units" in big:
        out["units"] = jax.tree.map(
            lambda b, s: b.at[:, slot_ids].set(s.astype(b.dtype)), big["units"], small["units"]
        )
    if "rem" in big:
        out["rem"] = jax.tree.map(
            lambda b, s: b.at[slot_ids].set(s.astype(b.dtype)), big["rem"], small["rem"]
        )
    return out
