"""Fault-isolating supervisor around ``ServeEngine.step()``.

The engine itself fails loudly: an injected (or organic) per-step fault
raises a :class:`~repro.serve.faults.ServeFault` naming the implicated
slots.  :class:`EngineSupervisor` is the containment layer — it catches
exactly those faults, quarantines only the offending slots (terminal
``RequestOutput`` with ``fault_reason``, KV blocks scrubbed and
released), and lets every other slot keep decoding **bit-identically to
a fault-free replay**: a ``step_error`` aborts the chunk before any
state commit, and a ``nonfinite_logits`` chunk commits healthy slots
before raising, so under greedy sampling no healthy token ever depends
on the fault (tests/test_faults.py proves this per matrix cell).

It also *delivers* scheduled faults from a
:class:`~repro.serve.faults.ServeFaultInjector`:

* decode faults (``step_error``, ``nonfinite_logits``) pass into
  ``engine.step(faults=...)``;
* ``pool_pressure`` allocs and holds free KV blocks for ``duration``
  steps — admission shortfalls then drive the engine's degraded-mode
  ladder (docs/SERVING.md §Fault tolerance);
* ``slow_step`` advances the injected clock before the step (latency
  only; requires a virtual clock to be observable).

After every ``audit_every`` steps the supervisor runs
``engine.audit()`` with its own held blocks declared as external refs,
so a single leaked block or refcount drift fails the run immediately.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.serve.engine import RequestOutput, ServeEngine
from repro.serve.faults import (
    FAULT_POOL_PRESSURE,
    FAULT_SLOW_STEP,
    FaultSpec,
    ServeFault,
    ServeFaultInjector,
)


class EngineSupervisor:
    """Wrap one engine; ``step()`` is a drop-in for ``engine.step()``."""

    def __init__(self, engine: ServeEngine,
                 injector: Optional[ServeFaultInjector] = None,
                 audit_every: int = 1):
        if audit_every < 0:
            raise ValueError("audit_every must be >= 0 (0 disables)")
        self.engine = engine
        self.injector = injector
        self.audit_every = audit_every
        self._step_no = 0
        # live pool-pressure holds: (release_at_step, block ids)
        self._held: List[Tuple[int, List[int]]] = []
        self.n_faults_injected = 0
        self.n_quarantined = 0
        self.audits_run = 0

    # ------------------------------------------------------------- step
    def step(self) -> List[RequestOutput]:
        step = self._step_no
        self._step_no += 1
        decode_faults: List[FaultSpec] = []
        for spec in (self.injector.pop(step) if self.injector else []):
            self.n_faults_injected += 1
            if spec.kind == FAULT_SLOW_STEP:
                advance = getattr(self.engine.clock, "advance", None)
                if advance is not None:  # wall clocks cannot be stalled
                    advance(spec.delay_s)
            elif spec.kind == FAULT_POOL_PRESSURE:
                self._hold_blocks(spec, step)
            else:
                decode_faults.append(spec)
        # release expired holds *before* the step: the engine sees
        # pressure exactly while a hold is live, and its ladder relaxes
        # on the first post-release admission
        self._release_expired(step)
        try:
            outs = self.engine.step(faults=decode_faults)
        except ServeFault as e:
            for slot_i in e.slots:
                self.engine.quarantine_slot(slot_i, e.reason)
                self.n_quarantined += 1
            outs = self.engine._drain()
        if self.audit_every and (step + 1) % self.audit_every == 0:
            self.engine.audit(external_refs=self.held_blocks)
            self.audits_run += 1
        return outs

    def run(self) -> List[RequestOutput]:
        """Drive to completion (like ``engine.run()``), fault-isolated."""
        outs: List[RequestOutput] = []
        while self.engine.has_work():
            outs.extend(self.step())
        self.release_all()
        return sorted(outs, key=lambda o: o.request_id)

    # ---------------------------------------------------- pool pressure
    @property
    def held_blocks(self) -> List[int]:
        return [b for _, blocks in self._held for b in blocks]

    def _hold_blocks(self, spec: FaultSpec, step: int) -> None:
        pool = self.engine._pool
        if pool is None:  # dense engine: no pool to pressure
            return
        n = min(spec.blocks or pool.n_free, pool.n_free)
        if n <= 0:
            return
        self._held.append((step + spec.duration, pool.alloc(n)))

    def _release_expired(self, step: int) -> None:
        live = []
        for release_at, blocks in self._held:
            if release_at <= step:
                for b in blocks:
                    self.engine._pool.decref(b)
            else:
                live.append((release_at, blocks))
        self._held = live

    def release_all(self) -> None:
        """Drop every outstanding pressure hold (end of run / teardown)."""
        for _, blocks in self._held:
            for b in blocks:
                self.engine._pool.decref(b)
        self._held = []

    # ------------------------------------------------------------ stats
    @property
    def stats(self) -> Dict[str, int]:
        return {
            "steps": self._step_no,
            "faults_injected": self.n_faults_injected,
            "quarantined": self.n_quarantined,
            "audits_run": self.audits_run,
            "held_blocks": len(self.held_blocks),
            "faults_pending": self.injector.n_pending if self.injector else 0,
        }
