"""Prefill packer: mixed-length prompts -> one prefill call.

Prompts are right-padded to the longest prompt in the admitted group and
run through a single XLA program; per-request true lengths mask everything
the padding could corrupt.  Two strategies, chosen per architecture:

* **full-seq** (``forward`` with ``return_states``) — one parallel pass
  over the packed grid.  Safe for pure global/cross-attention stacks even
  with padding: padded positions write garbage KV *above* each request's
  true length, and the decode path overwrites slot ``pos`` before its
  ``kv_len = pos+1`` mask ever exposes it.  Also safe for *any*
  architecture when all prompts have equal length (no padding at all).
* **masked scan** (``lax.scan`` over ``decode_step``) — one fused XLA
  program feeding the packed prompt token-by-token, with per-slot state
  updates gated on ``t < length``.  This is the generic fallback for
  recurrent blocks (RG-LRU, xLSTM) and sliding-window rings, whose states
  would absorb padding garbage under a padded full-sequence pass.

The chunked-prefill scheduler (``serve/scheduler.py``) adds a windowed
variant of the masked scan (``prefill_window``): one bounded chunk of
each slot's prompt, run *in place* over the engine's slotted state with
per-slot start offsets — admission never blocks decode for more than one
such bounded dispatch.  (The paged pure-attention path chunks through
``prefill_paged_suffix`` instead, which accepts arbitrary in-block start
offsets.)

Why sliding-window ("local") blocks are excluded from full-seq packing:
``_make_cache`` keeps only the last ``window`` positions of the *padded*
sequence, so a short request's real KV can be rolled out of the ring by
padding before decode ever starts.

MoE note: the engine prefils MoE architectures drop-free (capacity factor
= n_experts, mirroring the decode path's ``full_capacity``) so that padded
slots cannot compete real tokens out of expert capacity.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import Model
from repro.serve.slots import select_states

FULL_SEQ_KINDS = ("attn", "xattn")


def pack_prompts(prompts: Sequence[np.ndarray], cfg: ArchConfig,
                 pad_id: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Right-pad prompts to a common grid.

    Each prompt is ``[S_i]`` (or ``[C, S_i]`` multi-codebook).  Returns
    (tokens ``[B, S_max]`` / ``[B, C, S_max]``, lengths ``[B]`` int32).
    """
    if not prompts:
        raise ValueError("pack_prompts needs at least one prompt")
    lens = [int(np.asarray(p).shape[-1]) for p in prompts]
    if any(l == 0 for l in lens):
        # a real error, not an assert: it must survive `python -O`, and
        # ServeEngine.submit re-checks so the engine rejects before a slot
        # is ever claimed
        raise ValueError(f"empty prompt at index {lens.index(0)}: prompts "
                         "must contain at least one token")
    s_max = max(lens)
    rows = []
    for p in prompts:
        p = np.asarray(p, np.int32)
        pad = s_max - p.shape[-1]
        width = [(0, 0)] * (p.ndim - 1) + [(0, pad)]
        rows.append(np.pad(p, width, constant_values=pad_id))
    return jnp.asarray(np.stack(rows)), jnp.asarray(lens, jnp.int32)


def full_seq_packable(cfg: ArchConfig, lengths: Sequence[int]) -> bool:
    """Whether the padded full-sequence prefill is exact for this workload."""
    if len(set(int(l) for l in lengths)) <= 1:
        return True  # no padding, any architecture
    return all(k in FULL_SEQ_KINDS for k in cfg.layer_kinds)


def _drop_free(model: Model) -> Model:
    """MoE prefill runs drop-free (capacity = n_experts), mirroring the
    decode path's ``full_capacity``: padded slots must not compete real
    tokens out of expert capacity, and serving never drops tokens."""
    cfg = model.cfg
    if cfg.moe is not None and model.opts.capacity_factor < cfg.moe.n_experts:
        opts = dataclasses.replace(model.opts, capacity_factor=float(cfg.moe.n_experts))
        return dataclasses.replace(model, opts=opts)
    return model


def prefill_full_seq(model: Model, params, tokens: jax.Array, lengths: jax.Array,
                     max_len: int, vision_embeds: Optional[jax.Array] = None):
    """One parallel prefill over the packed grid.  Returns (last_logits, states)."""
    model = _drop_free(model)
    batch = {"tokens": tokens}
    if vision_embeds is not None:
        batch["vision_embeds"] = vision_embeds
    logits, states = model.prefill(params, batch, max_len=max_len)
    return _last_logits(logits, lengths), states


def prefill_scan(model: Model, params, tokens: jax.Array, lengths: jax.Array,
                 max_len: int):
    """Fused token-by-token prefill with per-slot masked state updates."""
    cfg = model.cfg
    b = tokens.shape[0]
    s = tokens.shape[-1]
    states0 = model.init_decode_state(b, max_len)
    toks_t = jnp.moveaxis(tokens, -1, 0)[..., None]  # [S, B, 1] | [S, B, C, 1]
    v = cfg.vocab
    last0 = jnp.zeros((b, 1, cfg.n_codebooks, v) if cfg.n_codebooks else (b, 1, v), jnp.float32)

    def step(carry, xs):
        states, last = carry
        t, tok = xs
        logits, new_states = model.decode(params, tok, states, t)
        active = t < lengths
        states = select_states(new_states, states, active)
        is_last = (t == lengths - 1).reshape((b,) + (1,) * (logits.ndim - 1))
        last = jnp.where(is_last, logits, last)
        return (states, last), None

    (states, last), _ = jax.lax.scan(
        step, (states0, last0), (jnp.arange(s, dtype=jnp.int32), toks_t)
    )
    return last, states


def prefill_window(model: Model, params, tokens: jax.Array, starts: jax.Array,
                   lengths: jax.Array, states):
    """One chunked-prefill window over the ENGINE state (dense layouts).

    The masked-scan prefill, windowed: feed slot ``b`` its next
    ``lengths[b]`` prompt tokens starting at absolute position
    ``starts[b]``, updating the full slotted state pytree in place with
    per-slot gating (``t >= lengths[b]`` leaves slot ``b`` untouched —
    rows with ``lengths[b] == 0``, i.e. slots that are decoding or free
    this round, ride along unchanged).  ``tokens`` is ``[B, L]`` (or
    ``[B, C, L]``), right-padded per slot.  Returns
    (last-position logits ``[B, 1, ...]``, updated states); the logits
    row is meaningful only for slots whose chunk ends at ``lengths[b]-1``
    — the engine reads it when that chunk completes the prompt.
    """
    model = _drop_free(model)
    return _window_jit(model)(params, tokens, starts, lengths, states)


@functools.lru_cache(maxsize=64)
def _window_jit(model: Model):
    cfg = model.cfg

    def f(params, tokens, starts, lengths, states):
        b = tokens.shape[0]
        s = tokens.shape[-1]
        toks_t = jnp.moveaxis(tokens, -1, 0)[..., None]  # [L, B, 1] | [L, B, C, 1]
        v = cfg.vocab
        last0 = jnp.zeros((b, 1, cfg.n_codebooks, v) if cfg.n_codebooks
                          else (b, 1, v), jnp.float32)

        def step(carry, xs):
            states, last = carry
            t, tok = xs
            logits, new_states = model.decode(params, tok, states, starts + t)
            active = t < lengths
            states = select_states(new_states, states, active)
            is_last = (t == lengths - 1).reshape((b,) + (1,) * (logits.ndim - 1))
            last = jnp.where(is_last, logits, last)
            return (states, last), None

        (states, last), _ = jax.lax.scan(
            step, (states, last0), (jnp.arange(s, dtype=jnp.int32), toks_t)
        )
        return last, states

    return jax.jit(f)


def _last_logits(logits: jax.Array, lengths: jax.Array) -> jax.Array:
    b = logits.shape[0]
    idx = (lengths - 1).reshape((b,) + (1,) * (logits.ndim - 1)).astype(jnp.int32)
    return jnp.take_along_axis(logits, jnp.broadcast_to(idx, (b, 1) + logits.shape[2:]), axis=1)


def prefill_paged_suffix(model: Model, params, tokens: jax.Array, lengths: jax.Array,
                         states, rows: jax.Array, starts: jax.Array, ctx_blocks: int):
    """Prefix-aware admission prefill against the paged KV pool.

    ``tokens [n, S_suf]`` are the admitted requests' *unprefilled
    suffixes* (right-padded), ``rows [n, W]`` their block-table rows,
    ``starts [n]`` the prefix lengths already resident in the pool — a
    block-aligned prefix-cache match, a chunked-prefill resume point at
    any in-block offset, or 0 for a cold request (also the cold path for
    pure-attention stacks under paging).  Returns (last_logits, updated
    pooled states).
    """
    model = _drop_free(model)
    return _suffix_jit(model)(params, tokens, lengths, states, rows, starts,
                              ctx_blocks=ctx_blocks)


@functools.lru_cache(maxsize=64)
def _suffix_jit(model: Model):
    def f(params, tokens, lengths, states, rows, starts, ctx_blocks):
        logits, states = model.prefill_suffix(params, tokens, states, rows,
                                              starts, ctx_blocks)
        return _last_logits(logits, lengths), states

    return jax.jit(f, static_argnames=("ctx_blocks",))


# jitted per-model wrappers: memoized on the (hashable, frozen) Model so
# all engine instances over the same model share one compile cache
@functools.lru_cache(maxsize=64)
def _full_seq_jit(model: Model):
    def f(params, tokens, lengths, vision_embeds, max_len):
        return prefill_full_seq(model, params, tokens, lengths, max_len, vision_embeds)

    return jax.jit(f, static_argnames=("max_len",))


@functools.lru_cache(maxsize=64)
def _scan_jit(model: Model):
    def f(params, tokens, lengths, max_len):
        return prefill_scan(model, params, tokens, lengths, max_len)

    return jax.jit(f, static_argnames=("max_len",))


def packed_prefill(model: Model, params, tokens: jax.Array, lengths: jax.Array,
                   max_len: int, vision_embeds: Optional[jax.Array] = None,
                   lengths_static: Optional[List[int]] = None,
                   force_scan: bool = False):
    """Dispatch to the exact prefill strategy for this arch x length mix.

    ``force_scan`` routes around the full-seq pass even when it would be
    numerically safe — the engine uses it when a sliding-window ring is
    larger than its pre-allocated ``max_len`` (the full-seq pass emits
    window-sized rings; the scan path always matches ``init_decode_state``).
    """
    lens = lengths_static if lengths_static is not None else list(np.asarray(lengths))
    if vision_embeds is None and "xattn" in model.cfg.layer_kinds:
        # no frontend embeddings supplied: forward() cannot build the
        # cross-attention KV.  Fall back to the scan path, which decodes
        # against the zeroed static xattn cache — the behavior the
        # pre-engine driver had for text-only runs of vision archs.
        force_scan = True
    if not force_scan and full_seq_packable(model.cfg, lens):
        return _full_seq_jit(model)(params, tokens, lengths, vision_embeds, max_len=max_len)
    if vision_embeds is not None:
        raise NotImplementedError(
            "mixed-length prefill with vision frontends needs a full-seq-safe stack"
        )
    return _scan_jit(model)(params, tokens, lengths, max_len=max_len)
