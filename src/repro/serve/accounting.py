"""Per-request ASTRA hardware accounting for the serve engine.

Each completed request gets the *modeled* photonic cost of its own
workload — prefill over the prompt plus one forward per generated token —
from ``core.simulator.simulate``, so serving reports measured tok/s and
the paper's latency/energy story side by side (DESIGN.md
§Arch-applicability describes what maps to VDPEs vs electronic NLUs).

Energy is also attributed per GEMM *site class* (the layer-stripped op id
from the shared execution/simulator registry, e.g. ``attn.qk`` or
``rglru.in_proj``) so a serving run can report where the photonic energy
goes under the active ExecutionPlan.

Next to the modeled chip cost, each request carries *measured* serving
latency (:class:`RequestTiming`): queue wait, time-to-first-token, and
inter-token latency, all anchored at **submission** (``submit``), not
admission — queue wait is part of the latency a caller observes, and the
chunked-prefill scheduler (docs/SERVING.md §Scheduling) is judged on
exactly these numbers.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.core.energy import AstraChipConfig
from repro.core.plan import site_class
from repro.core.simulator import simulate


@dataclasses.dataclass(frozen=True)
class RequestHardwareReport:
    latency_s: float
    energy_j: float
    macs: int
    energy_per_mac_j: float
    # energy attributed per site class (layer-stripped op id), descending
    energy_by_site: Tuple[Tuple[str, float], ...] = ()
    prompt_tokens: int = 0
    # prompt tokens served from the paged prefix cache — billed at ZERO
    # modeled ASTRA latency/energy (their KV was computed, and paid for,
    # by the request that interned it; docs/SERVING.md §Accounting)
    cached_prompt_tokens: int = 0

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["energy_by_site"] = dict(self.energy_by_site)
        return d


@dataclasses.dataclass(frozen=True)
class RequestTiming:
    """Measured (wall-clock) serving latency of one request.

    * ``queue_time_s`` — submission to admission into a slot;
    * ``ttft_s``       — submission to the first generated token arriving
      on the host (includes queue wait and every prefill chunk);
    * ``wall_time_s``  — submission to completion, true end to end;
    * ``mean_itl_s``   — (last token - first token) / (n_tokens - 1);
    * ``max_itl_s``    — the worst gap between consecutive token-arrival
      events.  A fused chunk delivers its tokens as one event, so this is
      chunk-granular — exactly the quantity a blocking full-prompt
      admission inflates for every other active slot.
    """

    queue_time_s: float
    ttft_s: float
    wall_time_s: float
    mean_itl_s: float
    max_itl_s: float
    n_token_events: int = 0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def request_timing(t_submit: float, t_admit: float, t_first: float,
                   token_events: Sequence[Tuple[float, int]],
                   t_done: float) -> RequestTiming:
    """Fold raw engine timestamps into a :class:`RequestTiming`.

    ``token_events`` is ``[(host_time, n_tokens)]`` in arrival order; the
    first event is the sampled first token (the TTFT token).  Requests
    that never decode (``max_new_tokens == 0``) pass an empty list.
    """
    n_tokens = sum(n for _, n in token_events)
    gaps = [b[0] - a[0] for a, b in zip(token_events, token_events[1:])]
    span = token_events[-1][0] - token_events[0][0] if token_events else 0.0
    return RequestTiming(
        queue_time_s=max(t_admit - t_submit, 0.0),
        ttft_s=max(t_first - t_submit, 0.0),
        wall_time_s=max(t_done - t_submit, 0.0),
        mean_itl_s=span / max(n_tokens - 1, 1),
        max_itl_s=max(gaps, default=0.0),
        n_token_events=len(token_events),
    )


@lru_cache(maxsize=4096)
def _simulate_cached(cfg: ArchConfig, chip: AstraChipConfig, seq: int):
    rep = simulate(cfg, chip, seq=seq, batch=1)
    by_site: Dict[str, float] = {}
    for c in rep.op_costs:
        key = site_class(c.name)
        by_site[key] = by_site.get(key, 0.0) + c.total_energy_j
    return rep.latency_s, rep.total_energy_j, rep.macs, tuple(sorted(by_site.items()))


def request_hardware_report(cfg: ArchConfig, chip: AstraChipConfig,
                            prompt_len: int, gen_len: int,
                            cached_prompt_len: int = 0) -> RequestHardwareReport:
    """Modeled chip cost of one request.

    Prefill is one forward over the prompt; each decode step is a forward
    over one token with the context it attends to — approximated (as the
    paper's methodology does) by a single forward at the final sequence
    length, which upper-bounds per-token context.

    ``cached_prompt_len`` prompt tokens hit the paged prefix cache: their
    KV was reused verbatim, so prefill is billed only over the unmatched
    suffix (decode still pays for attending to the full context).
    """
    lat = en = macs = 0.0
    sites: Dict[str, float] = {}
    billed_prompt = max(prompt_len - cached_prompt_len, 1)
    p_lat, p_en, p_macs, p_sites = _simulate_cached(cfg, chip, billed_prompt)
    lat, en, macs = lat + p_lat, en + p_en, macs + p_macs
    for k, v in p_sites:
        sites[k] = sites.get(k, 0.0) + v
    if gen_len > 0:
        # decode: gen_len single-token forwards amortized at full context
        d_lat, d_en, d_macs, d_sites = _simulate_cached(cfg, chip, prompt_len + gen_len)
        scale = gen_len / max(prompt_len + gen_len, 1)
        lat += d_lat * scale
        en += d_en * scale
        macs += d_macs * scale
        for k, v in d_sites:
            sites[k] = sites.get(k, 0.0) + v * scale
    by_site = tuple(sorted(sites.items(), key=lambda kv: -kv[1]))
    return RequestHardwareReport(lat, en, int(macs), en / max(macs, 1.0), by_site,
                                 prompt_tokens=prompt_len,
                                 cached_prompt_tokens=cached_prompt_len)
