"""Fused decode: N serving steps compiled into ONE XLA program.

The seed driver dispatched ``jit(decode)`` once per token — at small batch
sizes the per-dispatch host overhead (argument flattening, device sync,
python sampling) dominates the actual math.  Here the whole
decode->sample->feed-back loop is a ``jax.lax.scan`` body, so a chunk of
``steps`` tokens costs one dispatch and XLA pipelines the steps.

Positions are per-slot (``pos [B]``): the continuous-batching engine runs
slots at different absolute positions in the same fused chunk.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.serve.sampling import SamplerConfig, sample_next_token
from repro.serve.slots import select_states


@functools.lru_cache(maxsize=64)
def make_fused_decode(model: Model):
    """Build a jitted ``(params, tok, states, pos, key, steps, sampler)`` fn.

    Returns tokens ``[B, steps]`` (or ``[B, C, steps]``), plus the carried
    (next_tok, states, pos, key).  ``steps`` and ``sampler`` are static:
    each distinct chunk length compiles once and is cached by jit.
    Memoized per (hashable, frozen) ``Model`` so every engine instance over
    the same model shares one jit cache — no recompiles across engines.

    ``active`` (optional ``[B]`` bool) gates the per-slot state updates:
    inactive slots keep their state exactly.  The chunked-prefill engine
    passes it for dense layouts whenever a ``PREFILLING`` slot is present,
    so ride-along decode cannot corrupt a half-prefilled slot's recurrent
    state or KV.  (Paged layouts don't need it: a prefilling slot's block
    table points at the scratch block until it starts decoding.)  With
    ``active=None`` the program is unchanged from the maskless build.
    """

    def fused(params, tok, states, pos, key, steps: int, sampler: SamplerConfig,
              tables=None, active=None):
        def step(carry, _):
            tok, states, pos, key = carry
            logits, new_states = model.decode(params, tok, states, pos,
                                              block_tables=tables)
            states = (new_states if active is None
                      else select_states(new_states, states, active))
            key, sub = jax.random.split(key)
            nxt = sample_next_token(logits, sampler, sub, model.cfg)
            return (nxt, states, pos + 1, key), nxt

        carry, toks = jax.lax.scan(step, (tok, states, pos, key), length=steps)
        # toks [steps, B, 1] | [steps, B, C, 1] -> [B, steps] | [B, C, steps]
        toks = jnp.moveaxis(toks[..., 0], 0, -1)
        return toks, carry

    return jax.jit(fused, static_argnames=("steps", "sampler"))


@functools.lru_cache(maxsize=64)
def _jitted_decode(model: Model):
    # memoized so repeated unfused_decode calls stay warm — the benchmark
    # baseline must measure per-step dispatch, not re-trace/compile time
    return jax.jit(model.decode)


def unfused_decode(model: Model, params, tok, states, pos, key, steps: int,
                   sampler: SamplerConfig, tables=None,
                   active=None) -> Tuple[jax.Array, tuple]:
    """Seed-style reference loop: one ``jit(decode)`` dispatch per token.

    Kept as the parity oracle for the fused scan (and as the benchmark
    baseline the fused loop is measured against).  ``active`` mirrors the
    fused loop's optional per-slot state gate.
    """
    decode = _jitted_decode(model)
    out = []
    pos = jnp.asarray(pos, jnp.int32)
    for _ in range(steps):
        logits, new_states = decode(params, tok, states, pos, tables)
        states = (new_states if active is None
                  else select_states(new_states, states, active))
        key, sub = jax.random.split(key)
        tok = sample_next_token(logits, sampler, sub, model.cfg)
        out.append(tok)
        pos = pos + 1
    # out entries are [B, 1] (or [B, C, 1]); concat on -1 matches the scan layout
    toks = jnp.concatenate(out, axis=-1) if out else jnp.zeros(
        tok.shape[:-1] + (0,), jnp.int32
    )
    return toks, (tok, states, pos, key)
