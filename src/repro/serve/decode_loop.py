"""Fused decode: N serving steps compiled into ONE XLA program.

The seed driver dispatched ``jit(decode)`` once per token — at small batch
sizes the per-dispatch host overhead (argument flattening, device sync,
python sampling) dominates the actual math.  Here the whole
decode->sample->feed-back loop is a ``jax.lax.scan`` body, so a chunk of
``steps`` tokens costs one dispatch and XLA pipelines the steps.

Positions are per-slot (``pos [B]``): the continuous-batching engine runs
slots at different absolute positions in the same fused chunk.

Every chunk also returns a per-slot *finite* flag — ``True`` iff every
logit the slot produced across the chunk was finite — so NaN poisoning
(organic analog noise or an injected ``nonfinite_logits`` fault) is
detected at the step it happens and attributed to the right slot
(docs/SERVING.md §Fault tolerance).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.serve.sampling import SamplerConfig, sample_next_token
from repro.serve.slots import finite_mask, select_states


def _poisoned(logits, poison):
    """NaN out the logits of slots flagged in ``poison`` ([B] bool)."""
    shape = (-1,) + (1,) * (logits.ndim - 1)
    return jnp.where(poison.reshape(shape), jnp.nan, logits)


@functools.lru_cache(maxsize=64)
def make_fused_decode(model: Model):
    """Build a jitted ``(params, tok, states, pos, key, steps, sampler)`` fn.

    Returns tokens ``[B, steps]`` (or ``[B, C, steps]``), a per-slot
    ``finite [B]`` bool (ANDed across the chunk's steps), plus the carried
    (next_tok, states, pos, key).  ``steps`` and ``sampler`` are static:
    each distinct chunk length compiles once and is cached by jit.
    Memoized per (hashable, frozen) ``Model`` so every engine instance over
    the same model shares one jit cache — no recompiles across engines.

    ``active`` (optional ``[B]`` bool) gates the per-slot state updates:
    inactive slots keep their state exactly.  The chunked-prefill engine
    passes it for dense layouts whenever a ``PREFILLING`` slot is present,
    so ride-along decode cannot corrupt a half-prefilled slot's recurrent
    state or KV.  (Paged layouts don't need it: a prefilling slot's block
    table points at the scratch block until it starts decoding.)  With
    ``active=None`` the program is unchanged from the maskless build.

    ``poison`` (optional ``[B]`` bool) injects NaN into the flagged
    slots' logits inside the scan — the fault-injection stand-in for
    analog noise (serve/faults.py).  ``poison=None`` adds nothing to the
    traced program beyond the finite reduction itself.
    """

    def fused(params, tok, states, pos, key, steps: int, sampler: SamplerConfig,
              tables=None, active=None, poison=None):
        def step(carry, _):
            tok, states, pos, key, finite = carry
            logits, new_states = model.decode(params, tok, states, pos,
                                              block_tables=tables)
            if poison is not None:
                logits = _poisoned(logits, poison)
            finite = finite & finite_mask(logits)
            states = (new_states if active is None
                      else select_states(new_states, states, active))
            key, sub = jax.random.split(key)
            nxt = sample_next_token(logits, sampler, sub, model.cfg)
            return (nxt, states, pos + 1, key, finite), nxt

        finite0 = jnp.ones(tok.shape[0], dtype=bool)
        carry, toks = jax.lax.scan(step, (tok, states, pos, key, finite0),
                                   length=steps)
        tok, states, pos, key, finite = carry
        # toks [steps, B, 1] | [steps, B, C, 1] -> [B, steps] | [B, C, steps]
        toks = jnp.moveaxis(toks[..., 0], 0, -1)
        return toks, finite, (tok, states, pos, key)

    return jax.jit(fused, static_argnames=("steps", "sampler"))


@functools.lru_cache(maxsize=64)
def _jitted_decode(model: Model):
    # memoized so repeated unfused_decode calls stay warm — the benchmark
    # baseline must measure per-step dispatch, not re-trace/compile time
    return jax.jit(model.decode)


def unfused_decode(model: Model, params, tok, states, pos, key, steps: int,
                   sampler: SamplerConfig, tables=None, active=None,
                   poison=None) -> Tuple[jax.Array, jax.Array, tuple]:
    """Seed-style reference loop: one ``jit(decode)`` dispatch per token.

    Kept as the parity oracle for the fused scan (and as the benchmark
    baseline the fused loop is measured against).  ``active`` and
    ``poison`` mirror the fused loop's optional per-slot gates; the
    return layout matches too: ``(toks, finite, carry)``.
    """
    decode = _jitted_decode(model)
    out = []
    pos = jnp.asarray(pos, jnp.int32)
    finite = jnp.ones(tok.shape[0], dtype=bool)
    for _ in range(steps):
        logits, new_states = decode(params, tok, states, pos, tables)
        if poison is not None:
            logits = _poisoned(logits, poison)
        finite = finite & finite_mask(logits)
        states = (new_states if active is None
                  else select_states(new_states, states, active))
        key, sub = jax.random.split(key)
        tok = sample_next_token(logits, sampler, sub, model.cfg)
        out.append(tok)
        pos = pos + 1
    # out entries are [B, 1] (or [B, C, 1]); concat on -1 matches the scan layout
    toks = jnp.concatenate(out, axis=-1) if out else jnp.zeros(
        tok.shape[:-1] + (0,), jnp.int32
    )
    return toks, finite, (tok, states, pos, key)
