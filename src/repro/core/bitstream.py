"""Stochastic bit-stream generation — the B-to-S converter (paper Fig. 3).

A magnitude m in 0..127 becomes a 128-bit stream with exactly m ones.  The
*placement* of the ones is the generator policy; the hardware realizes it
with an LFSR-driven comparator (classic SC) or a unary counter (SCONNA-style).
We implement three faithful policies:

* ``thermometer`` — ones in positions [0, m).  Unary counter hardware.
* ``bresenham``   — m ones evenly spaced: bit_i = ((i+1)*m)//128 - (i*m)//128.
  This is "clock-division" deterministic SC; ANDed against a thermometer
  stream the popcount is round(m_x*m_w/128) to within 1 LSB, i.e. the
  deterministic-SC product used by unary optical accelerators.
* ``lfsr``        — ones placed at a pseudo-random permutation of positions.
  A maximal 7-bit LFSR visits every state in 0..126 exactly once per period,
  so LFSR-comparator hardware also yields *exactly* m ones per 128-cycle
  window — variance comes only from stream *pairing*, which this models.

Streams are packed little-endian into 4 uint32 words per operand:
``packed[..., w] bit b`` is stream position ``32*w + b``.

ASTRA's OSSM pairs an X stream with a W stream through an optical AND gate;
sign bits ride separately (XOR at the transducer).  See ``core/ossm.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import STREAM_LEN

N_WORDS = STREAM_LEN // 32  # 4

# A fixed permutation of 0..127 modelling the LFSR visit order.  Generated
# once from a 7-bit maximal LFSR (taps x^7 + x^6 + 1), state 0 appended last.
def _lfsr_order() -> tuple:
    state, order = 1, []
    for _ in range(127):
        order.append(state)
        bit = ((state >> 6) ^ (state >> 5)) & 1
        state = ((state << 1) | bit) & 0x7F
    order.append(0)
    return tuple(order)


LFSR_ORDER = _lfsr_order()


def _positions() -> jax.Array:
    return jnp.arange(STREAM_LEN, dtype=jnp.int32)


def stream_bits(mag: jax.Array, generator: str = "bresenham", phase: int = 0) -> jax.Array:
    """Magnitudes (int, 0..127, any shape) -> bits (..., 128) int32 in {0,1}.

    ``phase`` rotates the stream — hardware staggers LFSR seeds / counter
    phases across lanes to decorrelate; tests sweep it.
    """
    mag = jnp.asarray(mag, jnp.int32)
    i = (_positions() + phase) % STREAM_LEN  # (128,)
    m = mag[..., None]  # (..., 1)
    if generator == "thermometer":
        bits = (i < m).astype(jnp.int32)
    elif generator == "bresenham":
        # +STREAM_LEN//2 counter preset: ANDed against a thermometer stream
        # the popcount becomes round(m_x*m_w/128) instead of floor — exact
        # round-to-nearest deterministic SC, free in hardware (counter init).
        off = STREAM_LEN // 2
        bits = (((i + 1) * m + off) // STREAM_LEN - (i * m + off) // STREAM_LEN).astype(jnp.int32)
    elif generator == "lfsr":
        order = jnp.asarray(LFSR_ORDER, jnp.int32)
        bits = (order[i] < m).astype(jnp.int32)
    else:
        raise ValueError(f"unknown generator {generator!r}")
    return bits


def pack_bits(bits: jax.Array) -> jax.Array:
    """(..., 128) {0,1} -> (..., 4) uint32, little-endian within words."""
    b = bits.astype(jnp.uint32).reshape(*bits.shape[:-1], N_WORDS, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1).astype(jnp.uint32)


def unpack_bits(packed: jax.Array) -> jax.Array:
    """(..., 4) uint32 -> (..., 128) int32 {0,1}."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*packed.shape[:-1], STREAM_LEN).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("generator", "phase"))
def encode(mag: jax.Array, generator: str = "bresenham", phase: int = 0) -> jax.Array:
    """Magnitudes -> packed streams (..., 4) uint32.  The B-to-S circuit."""
    return pack_bits(stream_bits(mag, generator, phase))


def popcount(packed: jax.Array, axis: int = -1) -> jax.Array:
    """Total set bits across the word axis (the PCA charge count)."""
    return jnp.sum(jax.lax.population_count(packed).astype(jnp.int32), axis=axis)


def encode_signed(q: jax.Array, generator: str = "bresenham", phase: int = 0):
    """int8 two's-complement -> (packed_mag (...,4) uint32, sign (...,) int32 {+1,-1})."""
    mag = jnp.abs(q).astype(jnp.int32)
    sign = jnp.where(q < 0, -1, 1).astype(jnp.int32)
    return encode(mag, generator, phase), sign
