"""ASTRA core: the paper's contribution as a composable JAX module.

Layers (bottom-up):
  quant      — 8-bit sign-magnitude operand format
  bitstream  — B-to-S stream generation (thermometer/bresenham/lfsr), packing
  ossm       — optical stochastic signed multiplier (AND + popcount + sign)
  vdpe       — homodyne vector dot-product engine (pass tiling, PCA, ADC, noise)
  photonics  — device-level power/noise budget (Fig. 4)
  energy     — chip organization + per-component energy constants
  mapping    — output-stationary layer->VDPE mapping (latency/energy per GEMM)
  simulator  — whole-model rollup (Fig. 5, per-model latency/energy)
  baselines  — CPU/GPU/TPU/FPGA/TransPIM/LT/TRON/SCONNA models (Fig. 6)
  astra_layer— exact | int8 | sc execution modes for the model zoo
  plan       — per-site ExecutionPlan (site registry, glob rules, PTQ
               calibration) shared by execution and the simulator
"""
from repro.core.quant import QTensor, quantize, fake_quant, int8_matmul_exact, MAG_MAX, STREAM_LEN
from repro.core.astra_layer import (
    BoundSite, ComputeConfig, astra_batched_matmul, astra_matmul, EXACT, INT8, SC,
)
from repro.core.plan import (
    ExecutionPlan, PRESET_PLANS, SiteBinding, model_sites, site_class,
    validate_site_registry,
)
from repro.core.energy import AstraChipConfig
from repro.core.vdpe import VDPEConfig, sc_matmul

__all__ = [
    "QTensor", "quantize", "fake_quant", "int8_matmul_exact", "MAG_MAX", "STREAM_LEN",
    "BoundSite", "ComputeConfig", "astra_batched_matmul", "astra_matmul",
    "EXACT", "INT8", "SC",
    "ExecutionPlan", "PRESET_PLANS", "SiteBinding", "model_sites", "site_class",
    "validate_site_registry",
    "AstraChipConfig", "VDPEConfig", "sc_matmul",
]
