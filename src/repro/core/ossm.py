"""OSSM — Optical Stochastic Signed Multiplier (paper Fig. 1).

One OSSM multiplies an activation by a weight:

  1. both int8 operands are split into sign + 7-bit magnitude,
  2. magnitudes become 128-bit streams (B-to-S, ``core.bitstream``),
  3. the streams meet in an optical AND gate (OAG, Fig. 2): light passes in
     cycle t iff X_t AND W_t — the photodetector charge over the window is
     popcount(X & W),
  4. the sign is XOR(sign_x, sign_w), steering the charge onto the positive
     or negative rail of the balanced transducer.

With the deterministic pairing (thermometer x bresenham) the charge equals
round(m_x * m_w / 128) within 1 LSB — SC *without* random error; with LFSR
pairing it is the classic stochastic estimate.  ``ossm_multiply`` is the
bit-exact functional model used by tests and the accuracy study; the hot
path lives in ``repro.kernels.stoch_matmul``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitstream import encode_signed, popcount, STREAM_LEN
from repro.core.quant import QTensor

# Default stream pairing: X thermometer (unary counter on the activation
# serializer), W bresenham (clock-divided weight stream).  This is the
# deterministic-SC configuration ASTRA's accuracy numbers imply.
X_GEN = "thermometer"
W_GEN = "bresenham"


@functools.partial(jax.jit, static_argnames=("x_gen", "w_gen"))
def ossm_multiply(qx: jax.Array, qw: jax.Array, x_gen: str = X_GEN, w_gen: str = W_GEN) -> jax.Array:
    """Elementwise signed stochastic product, in integer popcount units.

    qx, qw: int8 arrays (broadcastable).  Returns int32 approximating
    qx*qw/128.  Multiply by 128*scale_x*scale_w to get real values.
    """
    xs, sx = encode_signed(qx, x_gen)
    ws, sw = encode_signed(qw, w_gen)
    pc = popcount(xs & ws)
    return pc * (sx * sw)


def ossm_expected(qx: jax.Array, qw: jax.Array) -> jax.Array:
    """The mathematical expectation of the OSSM (no stream rounding)."""
    return qx.astype(jnp.int32) * qw.astype(jnp.int32)


def sc_dot(qx: jax.Array, qw: jax.Array, x_gen: str = X_GEN, w_gen: str = W_GEN) -> jax.Array:
    """Dot product of int8 vectors through OSSMs + ideal analog accumulation.

    The PCA integrates all lane photocurrents linearly, so accumulation is an
    exact signed integer sum of per-lane popcounts.  Result approximates
    dot(qx, qw)/128 in popcount units.
    """
    return jnp.sum(ossm_multiply(qx, qw, x_gen, w_gen), axis=-1)


def sc_matmul_value(xq: QTensor, wq: QTensor, x_gen: str = X_GEN, w_gen: str = W_GEN) -> jax.Array:
    """Full stochastic matmul, dequantized: [..., K] @ [K, N] -> [..., N].

    Bit-exact but memory-heavy (materializes [..., K, N] popcounts) — the
    oracle for the Pallas kernel and for small-model accuracy studies.
    """
    prod = ossm_multiply(xq.q[..., :, None], wq.q[None, ...], x_gen, w_gen)  # [..., K, N]
    acc = jnp.sum(prod, axis=-2)  # analog accumulation over K
    return acc.astype(jnp.float32) * STREAM_LEN * xq.scale * wq.scale
