"""Per-site ExecutionPlan: one op-naming scheme for model execution, PTQ
calibration, and the architecture simulator.

ASTRA treats *static-weight* projections and *dynamic-tensor* attention
GEMMs (qk/pv) differently — the crosstalk-minimal OSSM organization and the
"dynamically-operated" accelerator designs both hinge on this split.  The
:class:`ExecutionPlan` makes that a first-class API: every GEMM the model
executes has a stable site id matching the simulator's op-graph names
(``L{layer}.{kind}.{op}``, plus ``lm_head``), and the plan maps sites to
:class:`~repro.core.astra_layer.ComputeConfig` via ordered glob rules:

    plan = ExecutionPlan.from_spec({"*.qk|*.pv": "int8", "*_proj": "sc",
                                    "default": "exact"})

Three cooperating pieces:

* **Resolution** — ``plan.resolve(site)`` walks the rules (first match
  wins; ``|`` separates glob alternatives) and falls back to ``default``.
  The scan-over-layers executes ONE trace for all pattern units, so a call
  site stands for a *group* of concrete layers (``L0.attn.qk, L2.attn.qk``,
  ...); ``resolve_group`` enforces that a plan cannot split a scanned group
  (layer-granular rules need unrolled/remainder layers).
* **Calibration** — ``plan.calibrate(model, params, batch)`` runs the model
  once in exact mode with per-site activation absmax observers
  (``jax.debug.callback`` taps inside ``astra_matmul``) and bakes per-site
  static ``act_scale`` values into the plan — replacing the single static
  float that nothing ever computed.
* **Registry cross-check** — ``model_sites(cfg)`` enumerates every executed
  GEMM site; ``validate_site_registry(cfg)`` asserts each resolves to
  exactly ONE op in ``core.simulator.model_ops``'s graph, so execution and
  the latency/energy model can never drift apart silently.

The legacy uniform API (``ModelOptions(cc=ComputeConfig("int8"))``) lowers
to ``ExecutionPlan.uniform(cc)``: ``cc`` everywhere *except* the dynamic
qk/pv sites and the MoE router/expert GEMMs, which stay exact —
bit-identical to the pre-plan behavior, where only ``dense()`` weights
were quantized.  Quantized attention and MoE are opt-in via explicit
rules (e.g. the ``"mixed"`` preset, or ``{"*.expert_*": "int8"}``).
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.astra_layer import EXACT, INT8, MODES, SC, BoundSite, ComputeConfig
from repro.core.quant import MAG_MAX

# Dynamic-tensor GEMM sites: both operands produced at run time (q·k^T and
# p·v).  ``uniform()`` pins these to exact; mlstm's decay-masked intra-chunk
# products and slstm's recurrent matvecs are not plan-routed at all (they
# run on the electronic side per DESIGN.md §Arch-applicability).
DYNAMIC_SITES = "*.qk|*.pv"
# MoE routing + grouped-dispatch expert GEMMs: the pre-plan code always ran
# these as exact einsums (the global cc never reached them), so the legacy
# shim pins them exact too; quantized MoE is opt-in via explicit rules.
MOE_SITES = "*.router|*.expert_up|*.expert_down"


def _match(pattern: str, site: str) -> bool:
    return any(fnmatch.fnmatchcase(site, alt) for alt in pattern.split("|"))


class _AbsMaxObserver:
    """Python-side accumulator for per-site activation absmax (calibration)."""

    def __init__(self):
        self.amax: Dict[str, float] = {}
        self.vec: Dict[str, np.ndarray] = {}  # KV sites: per-head absmax

    def record(self, sites: Tuple[str, ...], value) -> None:
        v = float(np.max(np.abs(np.asarray(value))))
        for s in sites:
            if v > self.amax.get(s, 0.0):
                self.amax[s] = v

    def record_vec(self, sites: Tuple[str, ...], value) -> None:
        """Elementwise (per-KV-head) absmax for KV storage sites."""
        v = np.asarray(value, np.float64)
        for s in sites:
            prev = self.vec.get(s)
            self.vec[s] = v.copy() if prev is None else np.maximum(prev, v)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Ordered glob rules -> per-site ComputeConfig, plus calibrated scales.

    Frozen and hashable (tuples only), so a ``Model`` carrying a plan stays
    a valid ``lru_cache`` key for the serve engine's jit memoization.
    """

    rules: Tuple[Tuple[str, ComputeConfig], ...] = ()
    default: ComputeConfig = EXACT
    act_scales: Tuple[Tuple[str, float], ...] = ()  # site -> static act scale
    # KV *storage* sites (``L{li}.kv.{k,v}``) -> per-KV-head static scales.
    # These quantize what the paged pool stores, not what a GEMM computes,
    # so they live beside act_scales rather than inside any ComputeConfig.
    kv_scales: Tuple[Tuple[str, Tuple[float, ...]], ...] = ()
    name: str = ""
    # Calibration tap.  compare=False keeps the plan hashable (observers
    # aren't value-comparable) — which also means an observing plan
    # compares EQUAL to its non-observing twin, so observing plans must
    # never enter equality-keyed caches: ``calibrate`` only uses one for a
    # single eager ``forward`` and discards it.  Don't hand one to the
    # serve engine or anything jit-memoized per Model.
    _observer: Optional[_AbsMaxObserver] = dataclasses.field(
        default=None, compare=False, repr=False
    )

    # ---------------------------------------------------------- resolution
    def resolve(self, site: str) -> ComputeConfig:
        """ComputeConfig for one concrete site (first matching rule wins)."""
        cc = next((cc for pat, cc in self.rules if _match(pat, site)), self.default)
        if cc.mode != "exact" and cc.act_scale is None:
            for s, scale in self.act_scales:
                if s == site:
                    cc = dataclasses.replace(cc, act_scale=scale)
                    break
        return cc

    def resolve_group(self, sites: Sequence[str]) -> ComputeConfig:
        """Resolve a group of sites sharing one scanned trace.

        All pattern units execute one trace under ``lax.scan``, so every
        layer in the group *must* resolve to the same config; a plan that
        splits the group is an error (per-layer plans need the unrolled
        remainder layers, or an unrolled model).
        """
        ccs = [self.resolve(s) for s in sites]
        first = ccs[0]
        for s, cc in zip(sites[1:], ccs[1:]):
            if cc != first:
                raise ValueError(
                    f"plan {self.name or self.rules!r} resolves {sites[0]!r} -> "
                    f"{first.mode} but {s!r} -> {cc.mode}; layers sharing a "
                    "scanned trace must resolve identically (layer-granular "
                    "rules only apply to unrolled/remainder layers)"
                )
        return first

    def site(self, name: str) -> BoundSite:
        return BoundSite(self, (name,))

    def binding(self, kind: str, layers: Sequence[int]) -> "SiteBinding":
        return SiteBinding(self, tuple(f"L{li}.{kind}" for li in layers))

    # ----------------------------------------------------------- KV storage
    def kv_scale(self, site: str) -> Optional[Tuple[float, ...]]:
        """Calibrated per-KV-head scales for one ``L{li}.kv.{k,v}`` site."""
        for s, scales in self.kv_scales:
            if s == site:
                return scales
        return None

    def kv_group_scale(self, sites: Sequence[str]) -> Tuple[float, ...]:
        """Per-head scales for a scanned group of KV storage sites.

        Layers sharing a scanned trace share one observer tap, so their
        recorded vectors are identical; the elementwise max is exact for
        them and conservative otherwise.  Raises if any site is missing —
        quantized KV storage without a calibrated scale is never legal.
        """
        vecs = []
        for s in sites:
            v = self.kv_scale(s)
            if v is None:
                raise ValueError(
                    f"plan {self.name or self.rules!r} has no calibrated KV "
                    f"scale for {s!r}; run Model.calibrate before enabling "
                    "kv_quant (static scales keep cached KV a pure function "
                    "of the token path)"
                )
            vecs.append(v)
        return tuple(float(x) for x in np.max(np.asarray(vecs), axis=0))

    # --------------------------------------------------------- construction
    @staticmethod
    def uniform(cc: ComputeConfig) -> "ExecutionPlan":
        """Legacy global-cc semantics: ``cc`` on every weight GEMM that the
        pre-plan code quantized; dynamic qk/pv and the MoE router/expert
        GEMMs stay exact (exactly what ``ModelOptions.cc`` did)."""
        return ExecutionPlan(rules=((DYNAMIC_SITES, EXACT), (MOE_SITES, EXACT)),
                             default=cc, name=f"uniform-{cc.mode}")

    @staticmethod
    def from_spec(spec: Union[str, Mapping, ComputeConfig, "ExecutionPlan"],
                  name: str = "") -> "ExecutionPlan":
        """Build a plan from a preset name, mode string, JSON string, or dict.

        Dict keys are glob rules (``|`` = alternatives) applied in order;
        the special key ``"default"`` sets the fallback.  Values are mode
        strings or ComputeConfig kwarg dicts.
        """
        if isinstance(spec, ExecutionPlan):
            return spec
        if isinstance(spec, ComputeConfig):
            return ExecutionPlan.uniform(spec)
        if isinstance(spec, str):
            s = spec.strip()
            if s in PRESET_PLANS:
                return PRESET_PLANS[s]
            if s in MODES:
                return ExecutionPlan.uniform(ComputeConfig(s))
            if s.startswith("{"):
                try:
                    return ExecutionPlan.from_spec(
                        json.loads(s), name=name or "<json>")
                except json.JSONDecodeError as e:
                    raise ValueError(f"invalid plan JSON: {e}") from e
            raise ValueError(
                f"unknown plan {spec!r}; valid presets: "
                f"{', '.join(sorted(PRESET_PLANS))}; valid uniform modes: "
                f"{', '.join(MODES)}; or pass JSON rules like "
                '\'{"*.qk|*.pv": "int8", "*_proj": "sc", "default": "exact"}\''
            )
        if isinstance(spec, Mapping):
            default = EXACT
            rules: List[Tuple[str, ComputeConfig]] = []
            for pat, val in spec.items():
                cc = _as_cc(val)
                if pat == "default":
                    default = cc
                else:
                    rules.append((pat, cc))
            return ExecutionPlan(tuple(rules), default, name=name)
        raise TypeError(f"cannot build ExecutionPlan from {type(spec).__name__}")

    # ---------------------------------------------------------- calibration
    def calibrate(self, model, params, batch) -> "ExecutionPlan":
        """One exact-mode forward with per-site absmax observers; returns a
        plan with per-site static ``act_scale`` baked in.

        ``model`` is a :class:`repro.models.model.Model`; ``batch`` is the
        usual ``{"tokens": [B, S], ...}`` dict (or a bare token array).
        Layers sharing a scanned trace share one observer tap, so their
        scale is the max over the group — exactly the granularity the plan
        can express for them.
        """
        import jax

        from repro.models.transformer import forward

        obs = _AbsMaxObserver()
        observe_plan = ExecutionPlan(name="calibrate", _observer=obs)
        opts = dataclasses.replace(model.opts, plan=observe_plan, cc=None,
                                   remat=False)  # remat would double-fire taps
        tokens = batch["tokens"] if isinstance(batch, Mapping) else batch
        vis = batch.get("vision_embeds") if isinstance(batch, Mapping) else None
        logits, _, _ = forward(params, tokens, model.cfg, opts, vision_embeds=vis)
        jax.block_until_ready(logits)
        jax.effects_barrier()  # flush the debug callbacks
        scales = tuple(sorted(
            (site, (amax / MAG_MAX) if amax > 0 else 1.0)
            for site, amax in obs.amax.items()
        ))
        kv = tuple(sorted(
            (site, tuple((a / MAG_MAX) if a > 0 else 1.0 for a in vec))
            for site, vec in obs.vec.items()
        ))
        return dataclasses.replace(self, act_scales=scales, kv_scales=kv)


def _as_cc(val: Union[str, Mapping, ComputeConfig]) -> ComputeConfig:
    if isinstance(val, ComputeConfig):
        return val
    if isinstance(val, str):
        return ComputeConfig(val)  # raises with the valid-mode list
    if isinstance(val, Mapping):
        return ComputeConfig(**val)
    raise TypeError(f"cannot build ComputeConfig from {type(val).__name__}")


PRESET_PLANS: Dict[str, ExecutionPlan] = {
    "exact": ExecutionPlan.uniform(EXACT),
    "int8": ExecutionPlan.uniform(INT8),
    "sc": ExecutionPlan.uniform(SC),
    # the hybrid photonic-digital split: int8 expectation on the
    # dynamic-tensor attention GEMMs, bit-true stochastic streams on the
    # static-weight projections, exact everywhere else
    "mixed": ExecutionPlan(
        rules=((DYNAMIC_SITES, INT8), ("*_proj", SC)), default=EXACT, name="mixed"
    ),
}


# ===================================================================== sites
@dataclasses.dataclass(frozen=True)
class SiteBinding:
    """Site-scoped view of a plan for one block instance (or scanned group).

    ``binding("qk")`` -> the :class:`BoundSite` covering
    ``L{li}.{kind}.qk`` for every layer ``li`` the trace stands for.
    """

    plan: ExecutionPlan
    prefixes: Tuple[str, ...]  # "L{li}.{kind}" per concrete layer

    def __call__(self, op: str) -> BoundSite:
        return BoundSite(self.plan, tuple(f"{p}.{op}" for p in self.prefixes))


def as_binding(cc: Union[ComputeConfig, SiteBinding]) -> SiteBinding:
    """Adapt a plain ComputeConfig (legacy direct calls into block fns) to
    the binding interface: uniform plan over an anonymous block."""
    if isinstance(cc, SiteBinding):
        return cc
    return SiteBinding(ExecutionPlan.uniform(cc), ("block",))


# ------------------------------------------------------------ KV storage sites
# Paged KV *storage* sites are named ``L{li}.kv.{k,v}`` — per layer, not per
# GEMM, because they quantize what the pool holds (post-rope keys, raw value
# projections) rather than an executed matmul.  They are deliberately NOT in
# ``model_sites``: the simulator op graph has no storage ops, and the 1:1
# ``validate_site_registry`` cross-check must keep holding.
_KV_KINDS = ("attn", "local")  # block kinds whose cache can live in the pool


def kv_site_names(prefixes: Sequence[str], which: str) -> Tuple[str, ...]:
    """``("L0.attn", "L2.attn"), "k"`` -> ``("L0.kv.k", "L2.kv.k")``."""
    assert which in ("k", "v")
    return tuple(f"{p.split('.', 1)[0]}.kv.{which}" for p in prefixes)


def kv_sites(cfg: ArchConfig) -> Tuple[str, ...]:
    """Every KV storage site of a config, in layer order."""
    return tuple(
        f"L{li}.kv.{which}"
        for li, kind in enumerate(cfg.layer_kinds)
        if kind in _KV_KINDS
        for which in ("k", "v")
    )


def observe_kv(sites: SiteBinding, k, v) -> None:
    """Calibration tap for KV storage sites: record per-KV-head absmax of
    exactly what decode would store (post-rope k, raw v).  No-op unless the
    binding's plan carries an observer (i.e. inside ``calibrate``)."""
    obs = sites.plan._observer
    if obs is None:
        return
    import functools

    import jax
    import jax.numpy as jnp

    for which, x in (("k", k), ("v", v)):
        names = kv_site_names(sites.prefixes, which)
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(0, 2, 3))
        jax.debug.callback(functools.partial(obs.record_vec, names), amax)


# The GEMM ops each block kind executes, named to match the simulator op
# graph (core.simulator._block_ops).  kv_proj covers both the wk and wv
# dense calls (the simulator models them as one fused d -> 2*kv_dim GEMM);
# "up" covers up+gate in gated MLPs the same way.
_ATTN_OPS = ("q_proj", "kv_proj", "qk", "pv", "o_proj")
_BLOCK_GEMMS: Dict[str, Tuple[str, ...]] = {
    "attn": _ATTN_OPS,
    "local": _ATTN_OPS,
    "xattn": _ATTN_OPS,
    "rglru": ("in_proj", "gates", "out_proj"),
    "mlstm": ("up_proj", "qkv", "gates", "down_proj"),
    "slstm": ("gates_in", "up", "down"),
}


def block_site_ops(cfg: ArchConfig, kind: str) -> Tuple[str, ...]:
    ops = list(_BLOCK_GEMMS[kind])
    has_mlp = kind in ("attn", "local", "xattn", "rglru") and (
        cfg.d_ff > 0 or cfg.moe is not None
    )
    if has_mlp:
        ops += ["router", "expert_up", "expert_down"] if cfg.moe is not None else ["up", "down"]
    return tuple(ops)


def model_sites(cfg: ArchConfig) -> Tuple[str, ...]:
    """Every GEMM site the model executes, in layer order, plus lm_head."""
    sites = [
        f"L{li}.{kind}.{op}"
        for li, kind in enumerate(cfg.layer_kinds)
        for op in block_site_ops(cfg, kind)
    ]
    sites.append("lm_head")
    return tuple(sites)


def site_class(op_name: str) -> str:
    """Aggregation key for per-site accounting: strip the layer index
    (``L3.attn.qk`` -> ``attn.qk``); non-layer ops pass through."""
    if op_name.startswith("L") and "." in op_name:
        head, rest = op_name.split(".", 1)
        if head[1:].isdigit():
            return rest
    return op_name


def validate_site_registry(cfg: ArchConfig, seq: int = 8) -> None:
    """Cross-check: every executed GEMM site resolves to exactly one
    simulator op-graph name.  Raises with the offending sites otherwise.

    (The converse need not hold: the simulator also models ops the zoo
    keeps on the electronic side — mlstm intra-chunk products, ViT patch
    embedding — and accounts them without a plan-routed execution site.)
    """
    from collections import Counter

    from repro.core.simulator import model_ops

    mm, _ = model_ops(cfg, seq=seq, batch=1)
    counts = Counter(op.name for op in mm)
    bad = {s: counts.get(s, 0) for s in model_sites(cfg) if counts.get(s, 0) != 1}
    if bad:
        raise AssertionError(
            f"{cfg.name}: executed GEMM sites without a 1:1 simulator op: {bad}"
        )
