"""Baseline platform models for the paper's comparison set (Fig. 6).

The paper compares ASTRA against CPU, GPU, TPU, FPGA ACC, TransPIM, LT
(Lightening-Transformer), TRON and SCONNA, normalized to CPU, claiming
>=7.6x speedup and >=1.3x lower energy vs the best accelerator and >1000x
energy savings vs CPU/GPU/TPU.

Each baseline is an analytic model: effective throughput = peak * util,
with *separate* utilization for static-weight GEMMs vs dynamic-operand
GEMMs (QK^T, PV).  Weight-stationary photonic designs (LT, TRON, SCONNA)
pay a reconfiguration stall on dynamic operands — exactly the gap ASTRA's
streamed-both-operands dataflow removes; DAC-based designs pay conversion
energy per operand element.  Batch-1 transformer inference on CPU/GPU/TPU
runs at single-digit utilization (latency-bound, published MLPerf-class
measurements) — that is what the paper's >1000x energy claim reflects.

All constants are representative literature values (# assumed where not in
the cited source); the *relative* Fig. 6 picture is the validation target.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import ArchConfig
from repro.core.simulator import ModelReport, model_ops


@dataclasses.dataclass(frozen=True)
class BaselineSpec:
    name: str
    peak_tops: float          # int8 (or equivalent) peak, TOPS (1 MAC = 2 ops)
    power_w: float            # board/device power while active
    util_static: float        # achieved fraction of peak on weight GEMMs, batch-1
    util_dynamic: float       # achieved fraction on dynamic-operand GEMMs
    conv_j_per_elem: float = 0.0   # DAC/ADC energy per streamed operand element
    reconfig_s_per_tile: float = 0.0  # weight-stationary reprogram per dynamic tile
    tile: int = 128
    kind: str = "electronic"
    notes: str = ""


# fmt: off
BASELINES: Dict[str, BaselineSpec] = {
    # General-purpose platforms: batch-1 FP32/bf16 transformer inference at
    # full board power — the comparison the paper's companion works (SCONNA
    # [4], ARTEMIS [2]) make for the ">1000x vs CPU/GPU/TPU" style claims.
    "cpu": BaselineSpec("cpu", peak_tops=3.0, power_w=205.0, util_static=0.004, util_dynamic=0.004,
                        notes="Xeon-class FP32; batch-1 util  # assumed (MLPerf-class)"),
    "gpu": BaselineSpec("gpu", peak_tops=31.0, power_w=300.0, util_static=0.02, util_dynamic=0.016,
                        notes="V100-class FP32 batch-1 (as in [4]); latency-bound  # assumed"),
    "tpu": BaselineSpec("tpu", peak_tops=90.0, power_w=280.0, util_static=0.012, util_dynamic=0.01,
                        notes="TPUv3-class bf16 batch-1  # assumed"),
    # Transformer accelerators.
    "fpga_acc": BaselineSpec("fpga_acc", peak_tops=1.0, power_w=25.0, util_static=0.45, util_dynamic=0.45,
                             kind="fpga", notes="FTRANS/NPE-class  # assumed"),
    "transpim": BaselineSpec("transpim", peak_tops=4.6, power_w=50.0, util_static=0.55, util_dynamic=0.55,
                             kind="pim", notes="HBM-PIM transformer acc  # assumed [TransPIM, HPCA'22]"),
    "lt": BaselineSpec("lt", peak_tops=100.0, power_w=90.0, util_static=0.5, util_dynamic=0.35,
                       conv_j_per_elem=5.2e-12, reconfig_s_per_tile=0.0, kind="photonic",
                       notes="Lightening-Transformer: dynamic photonic, DAC-heavy  # assumed [LT, HPCA'24]"),
    "tron": BaselineSpec("tron", peak_tops=30.0, power_w=40.0, util_static=0.5, util_dynamic=0.2,
                         conv_j_per_elem=3.9e-12, reconfig_s_per_tile=2e-6, kind="photonic",
                         notes="photonic transformer, partly weight-stationary MRRs (thermal retune)  # assumed [TRON, ISVLSI'23]"),
    "sconna": BaselineSpec("sconna", peak_tops=250.0, power_w=60.0, util_static=0.6, util_dynamic=0.04,
                           conv_j_per_elem=1.1e-12, reconfig_s_per_tile=4e-6, kind="photonic",
                           notes="stochastic photonic CNN acc [4]: weight-stationary MRR banks; "
                                 "dynamic GEMMs (QK^T/PV) force thermal MRR retuning (~us per tile)"),
}
# fmt: on


def simulate_baseline(spec: BaselineSpec, cfg: ArchConfig, seq: int, batch: int = 1) -> ModelReport:
    mm, ew = model_ops(cfg, seq, batch)
    peak_macs = spec.peak_tops * 1e12 / 2.0
    latency = 0.0
    conv_energy = 0.0
    macs = 0
    for op in mm:
        util = spec.util_dynamic if op.dynamic_w else spec.util_static
        latency += op.macs / (peak_macs * util)
        if spec.reconfig_s_per_tile and op.dynamic_w:
            tiles = -(-op.k // spec.tile) * -(-op.n // spec.tile) * op.count
            latency += tiles * spec.reconfig_s_per_tile
        if spec.conv_j_per_elem:
            elems = (op.m * op.k + op.k * op.n + op.m * op.n) * op.count
            conv_energy += elems * spec.conv_j_per_elem
        macs += op.macs
    # elementwise work: electronic platforms fold it into utilization; add
    # a 5% latency tax for photonic baselines that round-trip to electronics.
    if spec.kind == "photonic":
        latency *= 1.05
    energy = {"platform": latency * spec.power_w, "conversion": conv_energy}
    return ModelReport(f"{cfg.name}@{spec.name}", latency, energy, macs, [])


def compare_all(cfg: ArchConfig, chip, seq: int, batch: int = 1) -> List[ModelReport]:
    from repro.core.simulator import simulate

    reports = [simulate(cfg, chip, seq, batch)]
    for spec in BASELINES.values():
        reports.append(simulate_baseline(spec, cfg, seq, batch))
    return reports
