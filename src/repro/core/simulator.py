"""Architecture-level ASTRA simulator (paper §III methodology).

Walks a model config into its GEMM + elementwise op graph, maps every op
through ``core.mapping`` onto the ASTRA chip, and rolls up latency and
per-component energy.  Reproduces:

* Fig. 5 — energy breakdown by component,
* Fig. 6 / §III — latency + energy vs baseline platforms (``core.baselines``),
* the per-model numbers for the five paper models.

Elementwise/recurrent work that cannot map to VDPEs (softmax, norms, RG-LRU
and sLSTM recurrences, routing) runs on the electronic non-linear units —
see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.configs.base import ArchConfig
from repro.core.energy import AstraChipConfig
from repro.core.mapping import ElementwiseOp, MatmulOp, OpCost, map_elementwise, map_matmul

ENCODER_MODELS = {"bert-base", "albert-base", "vit-base", "transformer-base"}


def _attn_ops(cfg: ArchConfig, b: int, s: int, s_kv: int, name: str, cross: bool = False) -> List[MatmulOp]:
    d, hd, nh, nkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    t = b * s
    ops = [
        MatmulOp(f"{name}.q_proj", t, d, nh * hd),
        MatmulOp(f"{name}.kv_proj", (b * s_kv) if cross else t, d, 2 * nkv * hd),
        MatmulOp(f"{name}.qk", s, hd, s_kv, dynamic_w=True, count=b * nh),
        MatmulOp(f"{name}.pv", s, s_kv, hd, dynamic_w=True, count=b * nh),
        MatmulOp(f"{name}.o_proj", t, nh * hd, d),
    ]
    return ops


def _mlp_ops(cfg: ArchConfig, b: int, s: int, name: str) -> Tuple[List[MatmulOp], List[ElementwiseOp]]:
    t = b * s
    d = cfg.d_model
    mm: List[MatmulOp] = []
    ew: List[ElementwiseOp] = []
    if cfg.moe is not None:
        m = cfg.moe
        mm.append(MatmulOp(f"{name}.router", t, d, m.n_experts))
        # top-k dispatch: every token hits top_k experts
        mm.append(MatmulOp(f"{name}.expert_up", t * m.top_k, d, 2 * m.d_expert))
        mm.append(MatmulOp(f"{name}.expert_down", t * m.top_k, m.d_expert, d))
        ew.append(ElementwiseOp(f"{name}.route", t * m.n_experts * 3))  # softmax+topk
        ew.append(ElementwiseOp(f"{name}.glu", t * m.top_k * m.d_expert * 2))
    elif cfg.d_ff > 0:
        gated = cfg.act in ("swiglu", "geglu")
        mm.append(MatmulOp(f"{name}.up", t, d, (2 if gated else 1) * cfg.d_ff))
        mm.append(MatmulOp(f"{name}.down", t, cfg.d_ff, d))
        ew.append(ElementwiseOp(f"{name}.act", t * cfg.d_ff * (2 if gated else 1)))
    return mm, ew


def _block_ops(cfg: ArchConfig, kind: str, b: int, s: int, li: int, causal: bool) -> Tuple[List[MatmulOp], List[ElementwiseOp]]:
    d = cfg.d_model
    t = b * s
    name = f"L{li}.{kind}"
    mm: List[MatmulOp] = []
    ew: List[ElementwiseOp] = [ElementwiseOp(f"{name}.norms", t * d * 8)]
    if kind in ("attn", "local", "xattn"):
        if kind == "local":
            s_kv = min(s, cfg.window or s)
        elif kind == "xattn":
            s_kv = cfg.vision_tokens or s
        else:
            # causal attention averages s/2 effective context
            s_kv = s // 2 if causal else s
        mm += _attn_ops(cfg, b, s, max(s_kv, 1), name, cross=(kind == "xattn"))
        ew.append(ElementwiseOp(f"{name}.softmax", b * cfg.n_heads * s * max(s_kv, 1) * 5))
        m2, e2 = _mlp_ops(cfg, b, s, name)
        mm += m2
        ew += e2
    elif kind == "rglru":
        r = cfg.d_rnn
        mm.append(MatmulOp(f"{name}.in_proj", t, d, 2 * r))
        # RG-LRU recurrence+input gates (W_a, W_x): r -> r GEMMs the model
        # actually executes (site registry cross-check keeps this in sync)
        mm.append(MatmulOp(f"{name}.gates", t, r, 2 * r))
        mm.append(MatmulOp(f"{name}.out_proj", t, r, d))
        # conv1d + RG-LRU recurrence: elementwise, electronic (DESIGN.md)
        ew.append(ElementwiseOp(f"{name}.conv", t * r * 2 * cfg.conv_width))
        ew.append(ElementwiseOp(f"{name}.lru", t * r * 8))
        m2, e2 = _mlp_ops(cfg, b, s, name)
        mm += m2
        ew += e2
    elif kind == "mlstm":
        e = 2 * d
        hd = e // max(cfg.n_heads, 1)
        mm.append(MatmulOp(f"{name}.up_proj", t, d, 2 * e))
        # three e -> e projections (w_q, w_k, w_v), as the model executes
        mm.append(MatmulOp(f"{name}.qkv", t, e, 3 * e))
        # per-head input/forget gate projections (w_if)
        mm.append(MatmulOp(f"{name}.gates", t, e, 2 * cfg.n_heads))
        # chunkwise matrix-memory: intra-chunk attention-like products
        chunk = min(128, s)
        n_chunks = max(1, s // chunk)
        mm.append(MatmulOp(f"{name}.intra_qk", chunk, hd, chunk, dynamic_w=True, count=b * cfg.n_heads * n_chunks))
        mm.append(MatmulOp(f"{name}.intra_pv", chunk, chunk, hd, dynamic_w=True, count=b * cfg.n_heads * n_chunks))
        ew.append(ElementwiseOp(f"{name}.state", t * e * 6))  # inter-chunk C/n update
        mm.append(MatmulOp(f"{name}.down_proj", t, e, d))
    elif kind == "slstm":
        h = d
        mm.append(MatmulOp(f"{name}.gates_in", t, d, 4 * h))
        # post-cell GLU FFN (4/3 expansion), matching the executed block
        f_up = int(d * 4 / 3)
        mm.append(MatmulOp(f"{name}.up", t, h, 2 * f_up))
        mm.append(MatmulOp(f"{name}.down", t, f_up, d))
        # sequential scalar recurrence + recurrent matvecs: electronic
        ew.append(ElementwiseOp(f"{name}.recurrence", t * h * 10 + t * 4 * h * h // max(cfg.n_heads, 1) // 64))
    return mm, ew


def model_ops(cfg: ArchConfig, seq: int, batch: int = 1) -> Tuple[List[MatmulOp], List[ElementwiseOp]]:
    """The full inference op graph of one forward pass."""
    causal = cfg.name not in ENCODER_MODELS
    mm: List[MatmulOp] = []
    ew: List[ElementwiseOp] = []
    t = batch * seq
    if cfg.name == "vit-base":
        mm.append(MatmulOp("patch_embed", batch * 197, 16 * 16 * 3, cfg.d_model))
    for li, kind in enumerate(cfg.layer_kinds):
        m, e = _block_ops(cfg, kind, batch, seq, li, causal)
        mm += m
        ew += e
    heads = max(1, cfg.n_codebooks or 1)
    mm.append(MatmulOp("lm_head", t, cfg.d_model, cfg.vocab * heads))
    ew.append(ElementwiseOp("final_norm", t * cfg.d_model * 4))
    return mm, ew


@dataclasses.dataclass
class ModelReport:
    name: str
    latency_s: float
    energy_j: Dict[str, float]
    macs: int
    op_costs: List[OpCost]

    @property
    def total_energy_j(self) -> float:
        return sum(self.energy_j.values())

    @property
    def energy_per_mac_j(self) -> float:
        return self.total_energy_j / max(self.macs, 1)

    @property
    def throughput_macs(self) -> float:
        return self.macs / self.latency_s


def simulate(cfg: ArchConfig, chip: AstraChipConfig, seq: int, batch: int = 1) -> ModelReport:
    mm, ew = model_ops(cfg, seq, batch)
    costs = [map_matmul(chip, op) for op in mm] + [map_elementwise(chip, op) for op in ew]
    energy: Dict[str, float] = {}
    for c in costs:
        for k, v in c.energy_j.items():
            energy[k] = energy.get(k, 0.0) + v
    # ALBERT: one shared layer's weights stay SRAM-resident across all 12
    # repeats -> HBM weight traffic paid once.
    if cfg.name == "albert-base" and "hbm" in energy:
        energy["hbm"] /= cfg.n_layers
    # matmul VDPE time and NLU time overlap only partially: ASTRA pipelines
    # the NLU behind the VDPEs (non-linears depend on matmul outputs);
    # model 70% overlap.  # assumed
    t_mm = sum(c.latency_s for c in costs if c.macs > 0)
    t_ew = sum(c.latency_s for c in costs if c.macs == 0)
    latency = t_mm + 0.3 * t_ew
    macs = sum(c.macs for c in costs)
    return ModelReport(cfg.name, latency, energy, macs, costs)
