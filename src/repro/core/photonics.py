"""Photonic device-level model (paper §III "device-level analysis").

Models the optical power budget and noise of a homodyne VDPE so we can
reproduce Fig. 4 (scalability of OAGs-per-wavelength) and justify the
paper's 0.5 uW/OAG + 1024 OAGs/lambda operating point.

Power budget: laser light is split 1:N across N lanes (OSSMs); each lane
passes two microring modulators (X and W — the cascade is the optical AND)
plus waveguide propagation, then lands on the photo-charge accumulator's
photodetector.  The received optical energy per '1' bit must exceed the
detection threshold set by shot + thermal noise at the chosen BER.

All constants carry their source; values marked `# assumed` are
representative literature numbers chosen to match the paper's stated
operating point (0.5 uW/OAG after losses, >30 Gbps, 1024 OAGs/lambda).
"""
from __future__ import annotations

import dataclasses
import math

# physical constants
Q_ELECTRON = 1.602e-19  # C
K_B = 1.381e-23  # J/K


@dataclasses.dataclass(frozen=True)
class PhotonicParams:
    bitrate_hz: float = 30e9          # paper: >30 Gbps streams
    responsivity_a_w: float = 1.1     # Ge-on-Si PD  # assumed
    mod_il_db: float = 0.5            # microring insertion loss  # assumed [5]
    oag_il_db: float = 1.0            # optical AND gate IL  # assumed [5]
    splitter_il_db: float = 0.2       # per 1:2 split stage [6]
    waveguide_db_cm: float = 0.5      # propagation loss, low-loss SiN-assisted platform  # assumed
    lane_pitch_cm: float = 20e-4      # 20 um lane pitch  # assumed
    coupler_il_db: float = 1.0        # fiber-chip coupling  # assumed
    temp_k: float = 300.0
    tia_noise_a_rthz: float = 2e-12   # input-referred TIA noise  # assumed
    target_ber: float = 1e-4          # raw stream BER target (SC tolerates bit flips)
    laser_wallplug: float = 0.20      # comb laser wall-plug w/ run-time power mgmt  # assumed [7]
    rx_power_w: float = 0.5e-6        # paper: ~0.5 uW optical power per OAG


def db_to_lin(db: float) -> float:
    return 10.0 ** (db / 10.0)


def lane_loss_db(p: PhotonicParams, n_lanes: int) -> float:
    """Total insertion loss from laser to one lane's photodetector."""
    split_stages = max(1, math.ceil(math.log2(max(n_lanes, 2))))
    wg_len_cm = n_lanes * p.lane_pitch_cm
    return (
        p.coupler_il_db
        + split_stages * p.splitter_il_db
        + 2 * p.mod_il_db  # X and W modulators
        + p.oag_il_db
        + wg_len_cm * p.waveguide_db_cm
    )


def laser_power_w(p: PhotonicParams, n_lanes: int) -> float:
    """Laser output needed so every lane receives p.rx_power_w.

    Splitting is power division (1/N) *plus* excess loss per stage.
    """
    loss = db_to_lin(lane_loss_db(p, n_lanes))
    return p.rx_power_w * n_lanes * loss


def laser_wall_power_w(p: PhotonicParams, n_lanes: int) -> float:
    return laser_power_w(p, n_lanes) / p.laser_wallplug


def shot_noise_sigma_bits(p: PhotonicParams, n_lanes: int) -> float:
    """Std-dev of the per-pass accumulated charge, in units of one bit-charge.

    The PCA is an *integrating* receiver: it accumulates photo-charge over
    the whole 128-bit window, so its equivalent noise bandwidth is
    1/(2*T_window) — NOT the line-rate bandwidth a per-bit receiver would
    need.  Integrated shot-noise charge variance = q * I_avg * T (equivalent
    to Poisson counting: sigma_electrons = sqrt(N_electrons)); the TIA's
    input-referred current noise integrates the same way.  Worst case: all
    ``n_lanes`` carrying '1' the full window.  Normalized by the single-bit
    charge q1 = R * P_rx / bitrate so the VDPE simulator can add Gaussian
    noise directly in popcount units.
    """
    i_photo = p.responsivity_a_w * p.rx_power_w  # per-lane current when '1'
    window_s = 128.0 / p.bitrate_hz
    q1 = i_photo / p.bitrate_hz  # charge per bit
    i_total = i_photo * n_lanes  # worst case: all lanes on
    var_shot = Q_ELECTRON * i_total * window_s  # Poisson: q*I*T
    nbw = 1.0 / (2.0 * window_s)  # integrator noise bandwidth
    var_tia = (p.tia_noise_a_rthz**2) * nbw * window_s**2
    sigma_q = math.sqrt(var_shot + var_tia)
    return sigma_q / q1


def electrons_per_bit(p: PhotonicParams) -> float:
    """Photo-electrons collected per received '1' bit-slot."""
    q1 = p.responsivity_a_w * p.rx_power_w / p.bitrate_hz
    return q1 / Q_ELECTRON


def snr_db(p: PhotonicParams, n_lanes: int) -> float:
    """Single-bit detection SNR (electrical) at the PCA input."""
    i_photo = p.responsivity_a_w * p.rx_power_w
    bandwidth = p.bitrate_hz / 2
    sigma_i = math.sqrt(2 * Q_ELECTRON * i_photo * n_lanes * bandwidth + (p.tia_noise_a_rthz**2) * bandwidth)
    return 10 * math.log10(i_photo / sigma_i) if sigma_i > 0 else float("inf")


def max_lanes_at_power(p: PhotonicParams, max_laser_w: float) -> int:
    """Largest power-of-two lane count within a per-wavelength laser budget."""
    n = 2
    while n <= 65536 and laser_power_w(p, 2 * n) <= max_laser_w:
        n *= 2
    return n


def vdpe_scalability_table(p: PhotonicParams, lane_sweep=(64, 128, 256, 512, 1024, 2048)):
    """Fig. 4 reproduction: per-wavelength laser power & noise vs #OAGs."""
    rows = []
    for n in lane_sweep:
        rows.append(
            dict(
                lanes=n,
                loss_db=lane_loss_db(p, n),
                laser_mw=laser_power_w(p, n) * 1e3,
                laser_wall_mw=laser_wall_power_w(p, n) * 1e3,
                sigma_popcount=shot_noise_sigma_bits(p, n),
                snr_db=snr_db(p, n),
            )
        )
    return rows
