"""VDPE — homodyne Vector Dot-Product Engine (paper Fig. 3).

A VDPE holds up to 1024 OSSMs on a single wavelength; the photocurrents of
all lanes integrate on one photo-charge accumulator (PCA), i.e. the
accumulation across the K dimension is *analog and free*.  Longer dot
products are tiled into ceil(K/lanes) passes; the PCA keeps integrating
across passes (output-stationary), and a single ADC digitizes the final
value ("limiting ADC use to final outputs").

This module is the *noise-aware functional* model: exact integer popcount
math (matching ``repro.kernels.stoch_matmul``) plus optional shot-noise /
ADC-resolution effects from ``core.photonics`` for the Fig. 4 accuracy
study.  Inference-only; the deployable fast path is ``core.astra_layer``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import photonics
from repro.core.bitstream import STREAM_LEN
from repro.core.ossm import ossm_multiply, X_GEN, W_GEN
from repro.core.quant import QTensor


@dataclasses.dataclass(frozen=True)
class VDPEConfig:
    lanes: int = 1024
    x_gen: str = X_GEN
    w_gen: str = W_GEN
    adc_bits: int = 8
    noisy: bool = False
    photonic: photonics.PhotonicParams = dataclasses.field(default_factory=photonics.PhotonicParams)


def _pad_to_lanes(q: jax.Array, lanes: int, axis: int) -> jax.Array:
    pad = (-q.shape[axis]) % lanes
    if pad == 0:
        return q
    widths = [(0, 0)] * q.ndim
    widths[axis] = (0, pad)
    return jnp.pad(q, widths)


def sc_matmul(
    xq: QTensor,
    wq: QTensor,
    cfg: VDPEConfig = VDPEConfig(),
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Stochastic matmul through pass-tiled VDPEs: [M, K] @ [K, N] -> [M, N].

    Bit-exact popcount math; if ``cfg.noisy`` adds per-pass Gaussian shot
    noise (sigma from the photonic model, in popcount units) and quantizes
    the final accumulated value through the output ADC.
    """
    qx, qw = xq.q, wq.q
    m_dim, k_dim = qx.shape
    k2, n_dim = qw.shape
    assert k_dim == k2, (qx.shape, qw.shape)
    lanes = cfg.lanes
    qx = _pad_to_lanes(qx, lanes, 1)
    qw = _pad_to_lanes(qw, lanes, 0)
    n_pass = qx.shape[1] // lanes
    xp = jnp.moveaxis(qx.reshape(m_dim, n_pass, lanes), 1, 0)  # [P, M, lanes]
    wp = qw.reshape(n_pass, lanes, n_dim)  # [P, lanes, N]
    if cfg.noisy and key is None:
        key = jax.random.PRNGKey(0)
    # signal-dependent shot noise: the balanced PD rails integrate
    # N_e = |popcount| * electrons_per_bit photo-electrons; Poisson =>
    # sigma_popcount = sqrt(total_|counts| / electrons_per_bit).
    n_e = photonics.electrons_per_bit(cfg.photonic)

    def one_pass(acc, xs):
        x_t, w_t, idx = xs
        # [M, lanes, 1] x [1, lanes, N] -> popcounts [M, lanes, N]
        prod = ossm_multiply(x_t[:, :, None], w_t[None], cfg.x_gen, cfg.w_gen)
        pass_sum = jnp.sum(prod, axis=1).astype(jnp.float32)  # analog PCA integration
        if cfg.noisy:
            abs_counts = jnp.sum(jnp.abs(prod), axis=1).astype(jnp.float32)
            sigma = jnp.sqrt(abs_counts / n_e)
            noise = sigma * jax.random.normal(jax.random.fold_in(key, idx), pass_sum.shape)
            pass_sum = pass_sum + noise
        return acc + pass_sum, None

    acc0 = jnp.zeros((m_dim, n_dim), jnp.float32)
    acc, _ = jax.lax.scan(one_pass, acc0, (xp, wp, jnp.arange(n_pass)))

    if cfg.noisy:
        # single output ADC: digitize accumulated charge to adc_bits over the
        # observed dynamic range (hardware calibrates PGA gain the same way).
        rng = jnp.maximum(jnp.max(jnp.abs(acc)), 1.0)
        step = 2 * rng / (2**cfg.adc_bits)
        acc = jnp.round(acc / step) * step
    # popcount units -> real values
    return acc * STREAM_LEN * xq.scale * wq.scale


def sc_matmul_error(xq: QTensor, wq: QTensor, cfg: VDPEConfig, exact: jax.Array, key=None) -> float:
    """Relative L2 error of the SC result vs exact float matmul (Fig. 4)."""
    approx = sc_matmul(xq, wq, cfg, key=key)
    num = jnp.linalg.norm(approx - exact)
    den = jnp.maximum(jnp.linalg.norm(exact), 1e-9)
    return float(num / den)
