"""Layer -> VDP-core mapping with the output-stationary dataflow (paper §II).

ASTRA's dataflow: each output element y[m, n] is pinned to a PCA slot; its
K-dimension is streamed through a VDPE in ceil(K/lanes) passes, the PCA
integrating across passes, one ADC conversion at the end.  Both operands are
*streamed* (dynamically encoded in the optical domain), so matmuls with two
dynamic operands (QK^T, PV) cost the same as weight matmuls — no
weight-stationary reconfiguration penalty.  Within a core the X operand is
optically broadcast to all VDPEs (see ``core.energy``).

``map_matmul`` returns wall latency + per-component energy for one matmul;
``core.simulator`` walks whole models through it.  Which ops are
VDPE-mappable at all (vs routed to the electronic NLUs via
``map_elementwise``) is catalogued in DESIGN.md §Arch-applicability; the
chip organization being modeled is DESIGN.md §1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.energy import AstraChipConfig, ceil_div


@dataclasses.dataclass(frozen=True)
class MatmulOp:
    """One GEMM in the workload graph.

    dynamic_x / dynamic_w: whether the operand is produced at run time
    (activations, attention probs) or static (weights).  Static operands
    may be buffered in SRAM; a weight-stationary *baseline* would pay
    reconfiguration on dynamic operands — ASTRA does not.
    weight_reads: how many times the static operand must be fetched from
    HBM (1 unless it exceeds SRAM; ALBERT's sharing reduces unique bytes,
    not reads).
    """

    name: str
    m: int
    k: int
    n: int
    dynamic_x: bool = True
    dynamic_w: bool = False
    count: int = 1  # identical instances (e.g. per head, per layer)

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n * self.count

    @property
    def out_elems(self) -> int:
        return self.m * self.n * self.count


@dataclasses.dataclass
class OpCost:
    name: str
    latency_s: float
    energy_j: Dict[str, float]
    macs: int
    passes: int
    adc_convs: int

    @property
    def total_energy_j(self) -> float:
        return sum(self.energy_j.values())


def _merge(into: Dict[str, float], frm: Dict[str, float], scale: float = 1.0):
    for k, v in frm.items():
        into[k] = into.get(k, 0.0) + v * scale


def map_matmul(chip: AstraChipConfig, op: MatmulOp) -> OpCost:
    """Cost of one MatmulOp on the ASTRA chip, output-stationary mapping."""
    passes_per_out = ceil_div(op.k, chip.lanes)
    vdpe_passes = op.out_elems * passes_per_out
    # wall latency: all VDPEs run in parallel, fully pipelined
    latency = ceil_div(vdpe_passes, chip.total_vdpes) * chip.pass_time_s

    energy: Dict[str, float] = {}
    per_pass = chip.component_pass_energy_j()
    _merge(energy, per_pass, scale=float(vdpe_passes))
    # one ADC conversion per output element (in-situ accumulation across passes)
    energy["adc"] = op.out_elems * chip.e_adc_conv_j
    # SRAM traffic for outputs (int8 write-back after requantization)
    energy["sram"] = energy.get("sram", 0.0) + op.out_elems * chip.e_sram_byte_j
    # HBM traffic: static operands streamed from DRAM when not SRAM-resident.
    hbm_bytes = 0
    if not op.dynamic_w:
        w_bytes = op.k * op.n * op.count  # int8
        reads = 1 if w_bytes <= chip.sram_bytes else ceil_div(op.m, 1)  # re-stream per row tile if oversized
        hbm_bytes += w_bytes * min(reads, 4)  # cap: tiling bounds re-reads  # assumed
    if not op.dynamic_x:
        hbm_bytes += op.m * op.k * op.count
    energy["hbm"] = hbm_bytes * chip.e_hbm_byte_j
    return OpCost(op.name, latency, energy, op.macs, vdpe_passes, op.out_elems)


@dataclasses.dataclass(frozen=True)
class ElementwiseOp:
    """Non-matmul work routed to the electronic non-linear units."""

    name: str
    ops: int  # elementwise op count


def map_elementwise(chip: AstraChipConfig, op: ElementwiseOp) -> OpCost:
    latency = op.ops / chip.nlu_ops_per_s
    return OpCost(op.name, latency, {"nlu": op.ops * chip.e_nlu_op_j}, 0, 0, 0)
