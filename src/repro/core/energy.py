"""ASTRA chip organization + per-component energy/latency constants.

Architecture (paper Fig. 3): the chip holds ``n_cores`` VDP cores; each core
holds ``vdpes_per_core`` homodyne VDPEs of ``lanes`` OSSMs sharing one
wavelength.  Within a core the *activation* streams are modulated once and
optically fanned out (splitter tree) to all VDPEs — so X-side serializer /
B-to-S / modulator energy is amortized across ``vdpes_per_core`` outputs,
while W-side streams are per-VDPE.  This broadcast is what makes streaming
*both* operands affordable and is counted explicitly below.

Every energy constant is per-event and carries a provenance comment.
Absolute numbers for a 2-page paper are necessarily representative values
from the cited companion work (SCONNA [4], ARTEMIS [2], laser mgmt [7]);
the *relative* results (Figs 4-6, >=7.6x speedup, >=1.3x energy, >1000x vs
CPU/GPU/TPU) are what we validate against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import photonics
from repro.core.quant import STREAM_LEN


@dataclasses.dataclass(frozen=True)
class AstraChipConfig:
    """One ASTRA accelerator card.

    Dataflow amortization (output-stationary, both operands streamed):

    * **X optical broadcast** — within a core the activation stream is
      modulated once and split to all ``vdpes_per_core`` VDPEs (paper Fig. 3
      splitter tree), so X-side serializer / B-to-S / modulator energy is
      divided by ``vdpes_per_core``.
    * **W stream replay** — a weight vector pinned to a VDPE is reused for
      every output row of the output-stationary tile; the 128-bit pattern is
      B-to-S-converted ONCE into a local replay shift register and clocked
      out ``w_replay_reuse`` times.  Fresh (SRAM fetch + comparator +
      serializer) energy is paid 1/``w_replay_reuse`` per pass; the per-pass
      cost is the shift-register toggle (``e_replay_bit_j``) plus the
      modulator drive.

    These two reuses are the architectural reason ASTRA can stream 128-bit
    stochastic operands without paying 128x the electronics energy of an
    int8 design — the per-MAC electronics shrink to a few fJ/bit-slot.
    """

    n_cores: int = 64
    vdpes_per_core: int = 32
    lanes: int = 1024            # OSSMs (= OAGs) per VDPE, paper: up to 1024
    bitrate_hz: float = 30e9     # paper: >30 Gbps
    stream_len: int = STREAM_LEN # 128-bit streams + sign
    w_replay_reuse: int = 64     # output-stationary rows sharing one W encode
    x_replay_reuse: int = 64     # output-column tiles sharing one X encode
    # --- electrical energy per event (operating point calibrated to [5];
    #     each within published ranges for 7nm-class electronics / low-power
    #     silicon photonics) ---
    e_ser_bit_j: float = 10e-15     # serializer+SRAM fetch, J/bit (fresh encode)  # assumed [5]
    e_bts_bit_j: float = 5e-15      # B-to-S comparator+LFSR, J/bit  # assumed [4]
    e_replay_bit_j: float = 0.5e-15 # replay shift-register toggle, J/bit  # assumed
    e_mod_bit_j: float = 0.5e-15      # low-power microring drive, J/bit  # assumed (sub-fJ MRMs reported)
    e_pca_pass_j: float = 0.10e-12  # photo-charge accumulator per pass  # assumed [5]
    e_adc_conv_j: float = 2.6e-12   # 8-bit ADC per conversion (Murmann survey)  # assumed
    e_sram_byte_j: float = 0.08e-12 # on-chip SRAM access, CACTI  # assumed
    e_hbm_byte_j: float = 3.9e-12   # off-chip DRAM/HBM access  # assumed (ARTEMIS [2])
    e_nlu_op_j: float = 0.05e-12    # non-linear unit elementwise op  # assumed
    # --- digital/electronic throughput for non-matmul work ---
    nlu_ops_per_s: float = 8.0e12   # vectorized softmax/norm unit  # assumed
    sram_bytes: int = 64 * 2**20    # on-chip buffer capacity
    photonic: photonics.PhotonicParams = dataclasses.field(default_factory=photonics.PhotonicParams)

    @property
    def total_vdpes(self) -> int:
        return self.n_cores * self.vdpes_per_core

    @property
    def pass_time_s(self) -> float:
        """One stochastic pass: stream_len bit-slots at the line rate."""
        return self.stream_len / self.bitrate_hz

    @property
    def macs_per_pass(self) -> int:
        return self.total_vdpes * self.lanes

    @property
    def peak_macs_per_s(self) -> float:
        return self.macs_per_pass / self.pass_time_s

    @property
    def laser_wall_power_w(self) -> float:
        """Static laser wall power: one wavelength per VDPE."""
        per_vdpe = photonics.laser_wall_power_w(self.photonic, self.lanes)
        return per_vdpe * self.total_vdpes

    def component_pass_energy_j(self) -> Dict[str, float]:
        """Electrical energy of ONE VDPE pass (= ``lanes`` MACs), by component.

        X-side fresh-encode costs /= vdpes_per_core (optical broadcast);
        W-side fresh-encode costs /= w_replay_reuse (replay register);
        replay toggles and W modulator drive are per-pass; X modulator
        drive is amortized by the broadcast.
        """
        bits = self.lanes * self.stream_len
        # X: spatial broadcast across the core's VDPEs AND temporal replay
        # across output-column tiles (the same activation row multiplies
        # every weight column); W: temporal replay across output rows.
        x_share = 1.0 / self.vdpes_per_core
        w_share = 1.0 / self.w_replay_reuse
        fresh = w_share + x_share / self.x_replay_reuse
        return {
            "serializer": bits * self.e_ser_bit_j * fresh,
            "bts": bits * self.e_bts_bit_j * fresh,
            "replay": bits * self.e_replay_bit_j * (1.0 + x_share),  # W + bcast buf
            "oag_mod": bits * self.e_mod_bit_j * (1.0 + x_share),    # W mod + X mod/bcast
            "pca": self.e_pca_pass_j,
            "laser": (self.laser_wall_power_w / self.total_vdpes) * self.pass_time_s,
            "sram": self.lanes * fresh * self.e_sram_byte_j,  # int8 operand fetches
        }

    def energy_per_mac_j(self) -> float:
        return sum(self.component_pass_energy_j().values()) / self.lanes


# TPU v5e-like target constants for the roofline analysis (assignment-given).
TPU_PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
TPU_HBM_BW = 819e9       # bytes/s
TPU_ICI_BW = 50e9        # bytes/s per link


def adc_output_energy_j(chip: AstraChipConfig, n_outputs: int) -> float:
    return n_outputs * chip.e_adc_conv_j


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)
