"""8-bit sign-magnitude quantization — ASTRA's operand format (paper §III).

ASTRA streams both matmul operands through B-to-S converters, so *both*
activations and weights are quantized to 8 bits: a sign bit plus a 7-bit
magnitude (0..127) whose value becomes the density of a 128-bit stochastic
stream.  ``quantize`` produces standard two's-complement int8 in [-127, 127]
(the -128 code is unused, exactly as in sign-magnitude hardware); the
stream encoder takes ``abs`` and ``sign`` of it.

Weights use per-output-channel scales, activations per-tensor scales —
the usual PTQ recipe that the paper's "within 1.2% of FP32" result implies.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

MAG_MAX = 127  # 7-bit magnitude
STREAM_LEN = 128  # bits per stochastic stream (paper: 128-bit + sign)


class QTensor(NamedTuple):
    """Quantized tensor: int8 values + float scale (broadcastable)."""

    q: jax.Array  # int8, in [-127, 127]
    scale: jax.Array  # f32, broadcastable to q.shape

    @property
    def shape(self):
        return self.q.shape

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


def _safe_scale(amax: jax.Array) -> jax.Array:
    amax = jnp.asarray(amax, jnp.float32)
    return jnp.where(amax > 0, amax / MAG_MAX, 1.0)


def quantize(x: jax.Array, axis: Optional[int] = None, scale: Optional[jax.Array] = None) -> QTensor:
    """Symmetric int8 quantization.

    axis=None -> per-tensor scale; axis=k -> per-channel along k (scale shape
    keeps dims for broadcasting).  ``scale`` overrides calibration (static
    activation scales harvested offline).
    """
    xf = x.astype(jnp.float32)
    if scale is None:
        if axis is None:
            amax = jnp.max(jnp.abs(xf))
        else:
            amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
        scale = _safe_scale(amax)
    q = jnp.clip(jnp.round(xf / scale), -MAG_MAX, MAG_MAX).astype(jnp.int8)
    return QTensor(q, jnp.asarray(scale, jnp.float32))


def fake_quant(x: jax.Array, axis: Optional[int] = None) -> jax.Array:
    """Quantize-dequantize with a straight-through gradient (QAT option)."""
    qt = quantize(jax.lax.stop_gradient(x), axis=axis)
    y = qt.dequantize().astype(x.dtype)
    return x + jax.lax.stop_gradient(y - x)


class Calibrator:
    """Running absmax calibration for static activation scales (PTQ).

    Functional: ``state = Calibrator.init(); state = observe(state, x)``;
    EMA of per-tensor absmax, as used for the serving path's static scales.
    """

    decay = 0.99

    @staticmethod
    def init() -> jax.Array:
        return jnp.zeros((), jnp.float32)

    @staticmethod
    def observe(state: jax.Array, x: jax.Array) -> jax.Array:
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        return jnp.where(state == 0, amax, Calibrator.decay * state + (1 - Calibrator.decay) * amax)

    @staticmethod
    def scale(state: jax.Array) -> jax.Array:
        return _safe_scale(state)


def int8_matmul_exact(xq: QTensor, wq: QTensor) -> jax.Array:
    """Reference integer matmul + dequant — the *expectation* of ASTRA's
    stochastic computation (zero stream-rounding error).  [..., K] @ [K, N].
    """
    acc = jax.lax.dot_general(
        xq.q, wq.q,
        dimension_numbers=(((xq.q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * xq.scale * wq.scale
