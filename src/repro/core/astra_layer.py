"""ASTRA as a first-class execution mode for model matmuls.

``astra_matmul(x, w, mode)`` is the single entry point the model zoo uses
for every GEMM, so the whole framework can switch between:

* ``exact``  — bf16/f32 reference (training, dry-runs, baselines),
* ``int8``   — ASTRA *expectation*: symmetric int8 PTQ + integer matmul +
  dequant.  Bit-identical to the mean of the stochastic process (zero
  stream-rounding error); this is the deployable TPU fast path and what the
  dry-run lowers for serving.  Backed by ``repro.kernels.int8_matmul``.
* ``sc``     — bit-exact 128-bit stochastic stream simulation of the OSSM
  array (``repro.kernels.stoch_matmul``), used for accuracy validation.
  ~STREAM_LEN x the bytes of int8 — a validation mode, like the paper's own
  simulator.

Modes are threaded through the models via :class:`ComputeConfig`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, quantize

MODES = ("exact", "int8", "sc")


@dataclasses.dataclass(frozen=True)
class ComputeConfig:
    mode: str = "exact"
    x_gen: str = "thermometer"
    w_gen: str = "bresenham"
    use_pallas: bool = False  # Pallas kernels (interpret on CPU) vs jnp refs
    act_scale: Optional[float] = None  # static activation scale (PTQ-calibrated)

    def __post_init__(self):
        assert self.mode in MODES, self.mode


EXACT = ComputeConfig("exact")
INT8 = ComputeConfig("int8")
SC = ComputeConfig("sc")


def astra_matmul(x: jax.Array, w: jax.Array, cc: ComputeConfig = EXACT) -> jax.Array:
    """[..., K] @ [K, N] under the selected ASTRA execution mode."""
    if cc.mode == "exact":
        return jnp.matmul(x, w.astype(x.dtype))
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    xq = quantize(x2, axis=None, scale=cc.act_scale)
    wq = quantize(w, axis=0)  # per-output-channel
    if cc.mode == "int8":
        if cc.use_pallas:
            from repro.kernels.int8_matmul import ops as int8_ops

            out = int8_ops.int8_matmul(xq, wq)
        else:
            from repro.core.quant import int8_matmul_exact

            out = int8_matmul_exact(xq, wq)
    else:  # sc
        if cc.use_pallas:
            from repro.kernels.stoch_matmul import ops as sc_ops

            out = sc_ops.stoch_matmul(xq, wq, x_gen=cc.x_gen, w_gen=cc.w_gen)
        else:
            from repro.core.ossm import sc_matmul_value

            out = sc_matmul_value(xq, wq, cc.x_gen, cc.w_gen)
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)
