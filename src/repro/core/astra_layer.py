"""ASTRA as a first-class execution mode for model matmuls.

``astra_matmul(x, w, cc)`` is the single entry point the model zoo uses
for every GEMM, so the whole framework can switch between:

* ``exact``  — bf16/f32 reference (training, dry-runs, baselines),
* ``int8``   — ASTRA *expectation*: symmetric int8 PTQ + integer matmul +
  dequant.  Bit-identical to the mean of the stochastic process (zero
  stream-rounding error); this is the deployable TPU fast path and what the
  dry-run lowers for serving.  Backed by ``repro.kernels.int8_matmul``.
* ``sc``     — bit-exact 128-bit stochastic stream simulation of the OSSM
  array (``repro.kernels.stoch_matmul``), used for accuracy validation.
  ~STREAM_LEN x the bytes of int8 — a validation mode, like the paper's own
  simulator.

Modes are threaded through the models per GEMM *site*: ``cc`` may be a
plain :class:`ComputeConfig` (uniform behavior, the legacy API) or a
:class:`BoundSite` — a named GEMM site bound to an
:class:`~repro.core.plan.ExecutionPlan` that resolves it to a per-site
``ComputeConfig`` (and feeds the calibration observer during
``plan.calibrate``).  Site naming matches the architecture simulator's op
graph (``L3.attn.qk``, ``L0.rglru.in_proj``, ``lm_head``, ...) so executed
GEMMs and modeled ops share one registry — see ``repro.core.plan``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.quant import quantize

MODES = ("exact", "int8", "sc")


@dataclasses.dataclass(frozen=True)
class ComputeConfig:
    mode: str = "exact"
    x_gen: str = "thermometer"
    w_gen: str = "bresenham"
    use_pallas: bool = False  # Pallas kernels (interpret on CPU) vs jnp refs
    act_scale: Optional[float] = None  # static activation scale (PTQ-calibrated)

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown compute mode {self.mode!r}; valid modes: {', '.join(MODES)}"
            )


EXACT = ComputeConfig("exact")
INT8 = ComputeConfig("int8")
SC = ComputeConfig("sc")


@dataclasses.dataclass(frozen=True)
class BoundSite:
    """A named GEMM site (or a group of sites sharing one scanned trace)
    bound to an ExecutionPlan.  ``astra_matmul`` accepts this wherever it
    accepts a plain ComputeConfig; resolution happens at trace time.

    ``sites`` holds every *concrete* site id this call stands for — the
    scan-over-layers executes one trace for all pattern units, so a single
    call site covers ``L0.attn.qk, L2.attn.qk, ...`` at once.  The plan
    must resolve them identically (enforced by ``resolve_group``).
    """

    plan: object  # repro.core.plan.ExecutionPlan (duck-typed: no core->plan import)
    sites: Tuple[str, ...]

    def resolved(self) -> ComputeConfig:
        return self.plan.resolve_group(self.sites)

    @property
    def observing(self) -> bool:
        return getattr(self.plan, "_observer", None) is not None


def resolve_cc(cc: Union[ComputeConfig, BoundSite]) -> ComputeConfig:
    """Plain ComputeConfig for either form of ``cc`` (no observation)."""
    return cc.resolved() if isinstance(cc, BoundSite) else cc


def runs_exact(cc: Union[ComputeConfig, BoundSite]) -> bool:
    """Whether this GEMM takes the plain exact fast path — i.e. neither
    quantized nor tapped by a calibration observer."""
    return resolve_cc(cc).mode == "exact" and not (
        isinstance(cc, BoundSite) and cc.observing
    )


def _maybe_observe(cc: Union[ComputeConfig, BoundSite], x: jax.Array) -> None:
    """Feed the activation absmax to the plan's calibration observer (if
    any) — the single tap point shared by all astra matmul entry points."""
    if isinstance(cc, BoundSite):
        obs = getattr(cc.plan, "_observer", None)
        if obs is not None:
            amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
            jax.debug.callback(functools.partial(obs.record, cc.sites), amax)


def astra_matmul(
    x: jax.Array,
    w: jax.Array,
    cc: Union[ComputeConfig, BoundSite] = EXACT,
    *,
    site: Optional[str] = None,
    plan=None,
) -> jax.Array:
    """[..., K] @ [K, N] under the selected ASTRA execution mode.

    ``cc`` is either a uniform :class:`ComputeConfig` or a
    :class:`BoundSite`; alternatively pass ``site=`` and ``plan=`` to bind
    here (``astra_matmul(x, w, site="L0.attn.q_proj", plan=plan)``).
    """
    if plan is not None:
        names = (site,) if isinstance(site, str) else tuple(site or ("<anon>",))
        cc = BoundSite(plan, names)
    if isinstance(cc, BoundSite):
        _maybe_observe(cc, x)
        cc = cc.resolved()
    if cc.mode == "exact":
        return jnp.matmul(x, w.astype(x.dtype))
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    xq = quantize(x2, axis=None, scale=cc.act_scale)
    wq = quantize(w, axis=0)  # per-output-channel
    if cc.mode == "int8":
        if cc.use_pallas:
            from repro.kernels.int8_matmul import ops as int8_ops

            out = int8_ops.int8_matmul(xq, wq)
        else:
            from repro.core.quant import int8_matmul_exact

            out = int8_matmul_exact(xq, wq)
    else:  # sc
        if cc.use_pallas:
            from repro.kernels.stoch_matmul import ops as sc_ops

            out = sc_ops.stoch_matmul(xq, wq, x_gen=cc.x_gen, w_gen=cc.w_gen)
        else:
            from repro.core.ossm import sc_matmul_value

            out = sc_matmul_value(xq, wq, cc.x_gen, cc.w_gen)
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)


def astra_batched_matmul(x: jax.Array, w: jax.Array,
                         cc: Union[ComputeConfig, BoundSite]) -> jax.Array:
    """Batched GEMM with a *per-batch* second operand: ``[..., M, K] @
    [..., K, N]`` with shared leading dims — the dynamic-tensor form the
    attention qk/pv products and per-expert MoE GEMMs take.

    Exact mode stays a plain einsum; quantized modes vmap ``astra_matmul``
    over the flattened batch, which gives each batch element (e.g. each
    attention head) its own dynamic quantization scales — matching how the
    OSSM array streams both operands per tile.  Pallas kernels are 2-D; the
    batched path always uses the jnp references.
    """
    if runs_exact(cc):
        return jnp.matmul(x, w.astype(x.dtype))
    cc_run = dataclasses.replace(resolve_cc(cc), use_pallas=False)
    _maybe_observe(cc, x)
    lead = x.shape[:-2]
    xf = x.reshape((-1,) + x.shape[-2:])
    wf = jnp.broadcast_to(w, lead + w.shape[-2:]).reshape((-1,) + w.shape[-2:])
    out = jax.vmap(lambda a, b: astra_matmul(a, b, cc_run))(xf, wf)
    return out.reshape(lead + out.shape[-2:])
