"""Griffin / RecurrentGemma recurrent block (RG-LRU + temporal conv).

Block structure (arXiv:2402.19427):
    x -> linear (d -> 2r): [branch, gate]
    branch -> causal conv1d(width 4) -> RG-LRU -> * gelu(gate) -> linear (r -> d)

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a y_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x y_t + b_x)          (input gate)
    a_t = exp(c * softplus(Lambda) * (-r_t))   c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)

The elementwise recurrence itself is not a dot product — it runs on the
electronic side under ASTRA (DESIGN.md §Arch-applicability); the
projections and gates are VDPE-mappable GEMMs.  Sequence path uses the
``rglru_scan`` kernel (or its lax.scan oracle); decode carries (h, conv
window) state.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.astra_layer import ComputeConfig, EXACT
from repro.core.plan import SiteBinding, as_binding
from repro.models.layers import dense, dense_init
from repro.parallel.sharding import shard_act

C_LRU = 8.0


class RGLRUState(NamedTuple):
    h: jax.Array  # [B, r]
    conv: jax.Array  # [B, conv_width - 1, r] trailing inputs


def rglru_init(key, cfg: ArchConfig):
    r = cfg.d_rnn
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "w_in": dense_init(k1, cfg.d_model, 2 * r),
        "conv_w": jax.random.normal(k2, (cfg.conv_width, r), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((r,), jnp.float32),
        "w_a": dense_init(k3, r, r, bias=True),
        "w_x": dense_init(k4, r, r, bias=True),
        # Lambda init so a^c in [0.9, 0.999] at r_t=1 (Griffin init)
        "lam": jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, r)) / C_LRU)),
        "w_out": dense_init(k5, r, cfg.d_model),
    }


def _gates(p, y: jax.Array, sites: SiteBinding):
    """Returns (a, beta_x) with a = decay in (0,1), beta_x = scaled input."""
    rt = jax.nn.sigmoid(dense(p["w_a"], y, sites("gates")).astype(jnp.float32))
    it = jax.nn.sigmoid(dense(p["w_x"], y, sites("gates")).astype(jnp.float32))
    log_a = -C_LRU * jax.nn.softplus(p["lam"]) * rt  # [B, S, r] (<0)
    a = jnp.exp(log_a)
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, scale * it * y.astype(jnp.float32)


def _conv_seq(p, y: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Causal depthwise conv1d over [B, S, r]."""
    w = p["conv_w"]  # [cw, r]
    cw = cfg.conv_width
    pads = jnp.pad(y, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pads[:, i : i + y.shape[1], :] * w[i] for i in range(cw))
    return out + p["conv_b"]


def rglru_seq(
    p,
    x: jax.Array,  # [B, S, D]
    cfg: ArchConfig,
    sites: ComputeConfig | SiteBinding = EXACT,
    use_kernel: bool = False,
    return_state: bool = False,
) -> Tuple[jax.Array, RGLRUState | None]:
    b, s, _ = x.shape
    r = cfg.d_rnn
    sites = as_binding(sites)
    xz = shard_act(dense(p["w_in"], x, sites("in_proj")), ("batch", None, "rnn"))
    y, gate = xz[..., :r], xz[..., r:]
    y = _conv_seq(p, y, cfg)
    a, bx = _gates(p, y, sites)
    if use_kernel:
        from repro.kernels.rglru_scan import rglru_scan

        h = rglru_scan(a, bx)
    else:
        from repro.kernels.rglru_scan.ref import rglru_scan_ref

        h = rglru_scan_ref(a, bx)
    out = h.astype(x.dtype) * jax.nn.gelu(gate)
    out = dense(p["w_out"], out, sites("out_proj"))
    state = None
    if return_state:
        cw = cfg.conv_width
        # conv buffer holds the last cw-1 *pre-conv* inputs
        tail = jnp.pad(xz[..., :r], ((0, 0), (max(cw - 1 - s, 0), 0), (0, 0)))[:, -(cw - 1) :]
        state = RGLRUState(h[:, -1].astype(jnp.float32), tail.astype(jnp.float32))
    return out, state


def rglru_decode(
    p,
    x: jax.Array,  # [B, 1, D]
    state: RGLRUState,
    cfg: ArchConfig,
    sites: ComputeConfig | SiteBinding = EXACT,
) -> Tuple[jax.Array, RGLRUState]:
    r = cfg.d_rnn
    sites = as_binding(sites)
    xz = dense(p["w_in"], x, sites("in_proj"))
    y_new, gate = xz[..., :r], xz[..., r:]
    # conv over [state.conv ; y_new]
    hist = jnp.concatenate([state.conv, y_new.astype(jnp.float32)], axis=1)  # [B, cw, r]
    w = p["conv_w"]
    y = jnp.einsum("bcr,cr->br", hist, w)[:, None, :] + p["conv_b"]
    a, bx = _gates(p, y.astype(x.dtype), sites)
    h = a[:, 0] * state.h + bx[:, 0]
    out = h[:, None, :].astype(x.dtype) * jax.nn.gelu(gate)
    out = dense(p["w_out"], out, sites("out_proj"))
    new_state = RGLRUState(h, hist[:, 1:])
    return out, new_state
