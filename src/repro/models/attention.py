"""Attention blocks: global causal GQA, sliding-window local, cross-attention.

Three entry points per block:
  * ``attn_seq``    — full-sequence (training / prefill); optionally emits the
    KV cache for serving.
  * ``attn_decode`` — one-token step against a pre-allocated KV cache
    (global: [B, kv, S_max, hd] with position write; local: ring buffer of
    ``window``; cross: static frontend KV, read-only).
  * ``attn_prefill_paged`` — multi-token suffix prefill against a *paged*
    cache with past context (the serve engine's prefix-cache and
    chunked-prefill paths; suffixes may start at any in-block offset).

Serving caches come in two layouts (docs/SERVING.md):
  * dense ``KVCache`` — one max-length buffer per slot (the legacy layout);
  * paged ``PagedKVCache`` — a global pool of fixed-size blocks
    ``[n_blocks, n_kv, block_size, hd]`` addressed through per-slot block
    tables (``BlockTables``).  Reads gather the table into a logical view;
    writes scatter into the owning block.  Block 0 is a scratch sink for
    padded/overrun writes (never read at an unmasked position).

The softmax attention itself defaults to jnp einsum (XLA-native; gives the
dry-run an honest FLOP/byte profile) and can be swapped for the Pallas
kernels (``use_flash`` on the sequence path, ``use_kernel`` on the decode
and suffix-prefill paths) — all validated against each other in tests.

``use_kernel`` routes decode through ``kernels.paged_attention``: paged
caches stream K/V blocks straight from the pool via the block table (the
gathered ``_paged_view`` copy is never materialized), dense caches run a
length-masked single-query kernel instead of full-``max_len`` ``_sdpa``,
and paged suffix prefill streams its context the same way.  Like flash,
the kernels implement exact qk/pv only — when the plan quantizes either
dynamic site the astra-batched path is used and the kernel is bypassed.

GEMM sites: the projections are ``q_proj / kv_proj / o_proj`` (kv_proj
covers both wk and wv, matching the simulator's fused KV op); the
*dynamic-tensor* products are ``qk`` and ``pv``.  When the execution plan
resolves qk/pv to a quantized mode they run through
``astra_batched_matmul`` (per-head dynamic quantization — both operands
streamed, as the OSSM array does); the flash kernel only covers exact
qk/pv and is bypassed otherwise.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.astra_layer import (
    BoundSite, ComputeConfig, EXACT, astra_batched_matmul, runs_exact,
)
from repro.core.plan import SiteBinding, as_binding, observe_kv
from repro.core.quant import MAG_MAX
from repro.models.layers import apply_rope, dense, dense_init
from repro.parallel.sharding import shard_act


class KVCache(NamedTuple):
    k: jax.Array  # [B, n_kv, S_cache, hd]
    v: jax.Array  # [B, n_kv, S_cache, hd]


class PagedKVCache(NamedTuple):
    """Pooled KV storage: physical blocks shared by every slot."""

    k: jax.Array  # [n_blocks, n_kv, block_size, hd]
    v: jax.Array  # [n_blocks, n_kv, block_size, hd]


class QuantPagedKVCache(NamedTuple):
    """Int8 block pool + calibrated per-KV-head static scales.

    Same block geometry as :class:`PagedKVCache`, but payloads are stored
    as symmetric int8 against scales baked by the plan's calibration pass
    (``L{li}.kv.{k,v}`` sites).  Static scales keep every stored block a
    pure function of the token path — prefix reuse stays legal — and the
    paged-attention kernel dequantizes per streamed block, never
    materializing a dense dequantized view.
    """

    k: jax.Array  # [n_blocks, n_kv, block_size, hd] int8
    v: jax.Array  # [n_blocks, n_kv, block_size, hd] int8
    k_scale: jax.Array  # [n_kv] f32
    v_scale: jax.Array  # [n_kv] f32


AnyPagedKVCache = Union[PagedKVCache, QuantPagedKVCache]


def kv_quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Symmetric int8 quantization of a KV tensor.

    ``x`` carries KV heads on axis -3 (``[..., n_kv, S, hd]``); ``scale``
    ends in the per-head axis (``[n_kv]``, or with leading axes aligned to
    ``x``'s own leading axes, e.g. per-scan-unit scales).
    """
    s = jnp.asarray(scale, jnp.float32)[..., None, None]
    q = jnp.round(x.astype(jnp.float32) / s)
    return jnp.clip(q, -MAG_MAX, MAG_MAX).astype(jnp.int8)


def kv_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`kv_quantize` (up to the <= scale/2 rounding error)."""
    s = jnp.asarray(scale, jnp.float32)[..., None, None]
    return q.astype(jnp.float32) * s


class BlockTables(NamedTuple):
    """Per-slot logical->physical block mapping, shared across layers.

    ``table[b, i]`` is the physical block holding slot ``b``'s positions
    ``[i*bs, (i+1)*bs)`` (global attn) or ring slots in that range (local
    attn).  Unallocated entries point at scratch block 0.  ``ring_len`` is
    the sliding-window ring length for local layers (min(max_len, window));
    global layers ignore it.
    """

    table: jax.Array  # [B, W] int32
    ring_len: jax.Array  # [] int32


def attn_init(key, cfg: ArchConfig, cross: bool = False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, cfg.d_model, cfg.q_dim, bias=cfg.qkv_bias),
        "wk": dense_init(kk, cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias),
        "wv": dense_init(kv, cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.q_dim, cfg.d_model),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)  # [B, n, S, hd]


def _merge_heads(x: jax.Array) -> jax.Array:
    b, n, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, n * hd)


def _dyn_exact(bound: Optional[BoundSite]) -> bool:
    """Whether a dynamic-GEMM site runs the plain exact einsum path."""
    return bound is None or runs_exact(bound)


def _qk_scores(qg: jax.Array, k: jax.Array, bound: Optional[BoundSite]) -> jax.Array:
    """q·k^T per head group: [B,KV,G,Sq,hd] x [B,KV,Sk,hd] -> [B,KV,G,Sq,Sk]."""
    if _dyn_exact(bound):
        # keep operands in their storage dtype and accumulate in f32 via
        # preferred_element_type: avoids materializing an f32 copy of the
        # whole KV cache every decode step (2x cache bytes on the roofline)
        return jnp.einsum("bkgqd,bkld->bkgql", qg, k.astype(qg.dtype),
                          preferred_element_type=jnp.float32)
    b, kvh, g, sq, hd = qg.shape
    x = qg.reshape(b, kvh, g * sq, hd)
    w = jnp.swapaxes(k, -1, -2).astype(qg.dtype)  # [B,KV,hd,Sk]
    out = astra_batched_matmul(x, w, bound)
    return out.reshape(b, kvh, g, sq, -1).astype(jnp.float32)


def _pv_out(p: jax.Array, v: jax.Array, bound: Optional[BoundSite]) -> jax.Array:
    """probs·v per head group: [B,KV,G,Sq,Sk] x [B,KV,Sk,hd] -> [B,KV,G,Sq,hd]."""
    if _dyn_exact(bound):
        return jnp.einsum("bkgql,bkld->bkgqd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32)
    b, kvh, g, sq, sk = p.shape
    x = p.reshape(b, kvh, g * sq, sk).astype(v.dtype)
    out = astra_batched_matmul(x, v, bound)
    return out.reshape(b, kvh, g, sq, -1).astype(jnp.float32)


def _sdpa(q, k, v, *, causal: bool, window: int, q_offset: int | jax.Array = 0,
          kv_len: Optional[jax.Array] = None, softcap: float = 0.0,
          qk: Optional[BoundSite] = None, pv: Optional[BoundSite] = None) -> jax.Array:
    """jnp attention. q [B,H,Sq,hd], k/v [B,KV,Sk,hd]; GQA via head groups.

    ``kv_len`` may be a scalar or a per-batch ``[B]`` vector (the serve
    engine's continuous batching runs slots at different positions).
    ``qk``/``pv`` are the plan-bound dynamic-GEMM sites (None = exact).
    """
    b, h, sq, hd = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, hd)
    s = _qk_scores(qg, k, qk)
    s = s * (hd ** -0.5)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    if jnp.ndim(q_offset) == 1:
        # per-batch query offsets (paged suffix prefill: each slot's suffix
        # starts at its own absolute position) -> [B, sq, sk] masks
        q_pos = jnp.arange(sq)[None, :, None] + jnp.asarray(q_offset)[:, None, None]
        k_pos = jnp.arange(sk)[None, None, :]
        m = q_pos >= k_pos if causal else jnp.ones((1, sq, sk), bool)
        if window > 0:
            m &= (q_pos - k_pos) < window
        if kv_len is not None:
            kl = jnp.asarray(kv_len)
            m &= k_pos < (kl[:, None, None] if kl.ndim == 1 else kl)
        s = jnp.where(m[:, None, None], s, -1e30)
    else:
        q_pos = jnp.arange(sq)[:, None] + q_offset
        k_pos = jnp.arange(sk)[None, :]
        mask = jnp.ones((sq, sk), bool)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        if kv_len is not None and jnp.ndim(kv_len) == 1:
            bmask = mask[None] & (k_pos[None] < kv_len[:, None, None])  # [B, sq, sk]
            s = jnp.where(bmask[:, None, None], s, -1e30)
        else:
            if kv_len is not None:
                mask &= k_pos < kv_len
            s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = _pv_out(p, v, pv)
    return o.reshape(b, h, sq, hd).astype(q.dtype)


def attn_seq(
    p,
    x: jax.Array,  # [B, S, D]
    cfg: ArchConfig,
    *,
    kind: str,  # attn | local | xattn
    sites: Union[ComputeConfig, SiteBinding] = EXACT,
    use_flash: bool = False,
    positions: Optional[jax.Array] = None,
    kv_src: Optional[jax.Array] = None,  # cross-attn memory [B, T, D]
    return_cache: bool = False,
    max_len: Optional[int] = None,  # pre-allocated cache length for serving
) -> Tuple[jax.Array, Optional[KVCache]]:
    b, s, d = x.shape
    sites = as_binding(sites)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    src = kv_src if kind == "xattn" else x
    q = _split_heads(dense(p["wq"], x, sites("q_proj")), cfg.n_heads, cfg.head_dim)
    k = _split_heads(dense(p["wk"], src, sites("kv_proj")), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(dense(p["wv"], src, sites("kv_proj")), cfg.n_kv_heads, cfg.head_dim)
    q = shard_act(q, ("batch", "heads", None, None))
    k = shard_act(k, ("batch", "heads", None, None))
    v = shard_act(v, ("batch", "heads", None, None))
    if kind != "xattn":
        q = apply_rope(q, positions, cfg.rope_pct, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_pct, cfg.rope_theta)
        # KV storage-site calibration tap: exactly what decode/prefill
        # would store in the pool (post-rope k, raw v); no-op outside
        # plan.calibrate
        observe_kv(sites, k, v)
    causal = kind != "xattn"
    window = cfg.window if kind == "local" else 0
    qk_b, pv_b = sites("qk"), sites("pv")
    # the flash kernel implements exact qk/pv only; quantized dynamic GEMMs
    # take the astra-batched path inside _sdpa
    if use_flash and kind != "xattn" and _dyn_exact(qk_b) and _dyn_exact(pv_b):
        from repro.kernels.flash_attention import flash_attention

        o = flash_attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.logit_softcap)
    else:
        o = _sdpa(q, k, v, causal=causal, window=window, softcap=cfg.logit_softcap,
                  qk=qk_b, pv=pv_b)
    o = shard_act(o, ("batch", "heads", None, None))
    out = shard_act(dense(p["wo"], _merge_heads(o), sites("o_proj")), ("batch", None, None))
    cache = None
    if return_cache:
        cache = _make_cache(k, v, kind, cfg, s, max_len)
    return out, cache


def _make_cache(k, v, kind: str, cfg: ArchConfig, s: int, max_len: Optional[int]) -> KVCache:
    """Build the serving cache. Global: padded to max_len (decode writes at
    slot=pos).  Local: ring of size ``window`` where absolute position t
    lives at slot t % window (decode keeps writing at pos % window)."""
    if kind == "local" and cfg.window:
        w = cfg.window
        if s >= w:
            last_k, last_v = k[:, :, -w:], v[:, :, -w:]
            shift = s % w
            return KVCache(jnp.roll(last_k, shift, axis=2), jnp.roll(last_v, shift, axis=2))
        pad = w - s
        return KVCache(
            jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
            jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
        )
    if kind == "xattn":
        return KVCache(k, v)
    tgt = max(max_len or 0, s + 1)
    pad = tgt - s
    return KVCache(
        jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
        jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
    )


def init_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype=None) -> KVCache:
    """Zeroed decode cache.  Cache dtype follows the model dtype so the
    decode path and a full-sequence prefill (which emits KV in model
    dtype) agree bit-for-bit — bf16 models keep the compact bf16 cache."""
    if dtype is None:
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if kind == "local" and cfg.window:
        max_len = min(max_len, cfg.window)
    if kind == "xattn":
        max_len = cfg.vision_tokens
    shape = (batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def init_paged_cache(cfg: ArchConfig, n_blocks: int, block_size: int,
                     dtype=None) -> PagedKVCache:
    """Zeroed block pool for one attention layer (global or local kind).
    Same dtype rule as :func:`init_cache`."""
    if dtype is None:
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    shape = (n_blocks, cfg.n_kv_heads, block_size, cfg.head_dim)
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def init_paged_quant_cache(cfg: ArchConfig, n_blocks: int, block_size: int,
                           k_scale, v_scale) -> QuantPagedKVCache:
    """Zeroed int8 block pool with calibrated per-KV-head scales baked in."""
    shape = (n_blocks, cfg.n_kv_heads, block_size, cfg.head_dim)
    return QuantPagedKVCache(
        jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
        jnp.asarray(k_scale, jnp.float32), jnp.asarray(v_scale, jnp.float32))


def _paged_view(cache: AnyPagedKVCache, table: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Gather each slot's logical KV from the pool.

    table [B, W] -> k/v [B, n_kv, W*block_size, hd]: logical position ``p``
    of slot ``b`` lives at ``pool[table[b, p // bs], :, p % bs]``.
    Quantized pools are dequantized after the gather (this is the naive
    materializing path; the kernel path never builds this view).
    """
    def gather(pool):
        nb, kvh, bs, hd = pool.shape
        g = pool[table]  # [B, W, kv, bs, hd]
        return jnp.moveaxis(g, 1, 2).reshape(table.shape[0], kvh, -1, hd)

    k, v = gather(cache.k), gather(cache.v)
    if isinstance(cache, QuantPagedKVCache):
        k = kv_dequantize(k, cache.k_scale)
        v = kv_dequantize(v, cache.v_scale)
    return k, v


def _paged_write_token(cache: AnyPagedKVCache, table: jax.Array, slot: jax.Array,
                       k_new: jax.Array, v_new: jax.Array) -> AnyPagedKVCache:
    """Scatter one token per batch row into its block.  slot [B] is the
    logical cache position (absolute pos, or ring slot for local attn);
    k_new/v_new [B, n_kv, 1, hd].  Rows sharing a physical block (only the
    scratch sink, by engine invariant) race benignly."""
    bs = cache.k.shape[2]
    b = slot.shape[0]
    if isinstance(cache, QuantPagedKVCache):
        k_new = kv_quantize(k_new, cache.k_scale)
        v_new = kv_quantize(v_new, cache.v_scale)
    pb = table[jnp.arange(b), slot // bs]  # [B] physical block per row
    off = slot % bs
    k = cache.k.at[pb, :, off].set(k_new[:, :, 0].astype(cache.k.dtype))
    v = cache.v.at[pb, :, off].set(v_new[:, :, 0].astype(cache.v.dtype))
    return cache._replace(k=k, v=v)


def attn_decode(
    p,
    x: jax.Array,  # [B, 1, D]
    cache: Union[KVCache, AnyPagedKVCache],
    pos: jax.Array,  # [] int32 — absolute position of the new token, or [B]
    cfg: ArchConfig,
    *,
    kind: str,
    sites: Union[ComputeConfig, SiteBinding] = EXACT,
    tables: Optional[BlockTables] = None,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Union[KVCache, AnyPagedKVCache]]:
    b = x.shape[0]
    sites = as_binding(sites)
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1  # continuous batching: each slot at its own pos
    q = shard_act(
        _split_heads(dense(p["wq"], x, sites("q_proj")), cfg.n_heads, cfg.head_dim),
        ("batch", "heads", None, None),
    )
    posb = pos[:, None] if per_slot else jnp.broadcast_to(pos[None, None], (b, 1))
    qk_b, pv_b = sites("qk"), sites("pv")
    if kind == "xattn":
        # static frontend KV; no rope, full visibility
        o = _sdpa(q, cache.k, cache.v, causal=False, window=0, softcap=cfg.logit_softcap,
                  qk=qk_b, pv=pv_b)
        return dense(p["wo"], _merge_heads(o), sites("o_proj")), cache
    k_new = _split_heads(dense(p["wk"], x, sites("kv_proj")), cfg.n_kv_heads, cfg.head_dim)
    v_new = _split_heads(dense(p["wv"], x, sites("kv_proj")), cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, posb, cfg.rope_pct, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_pct, cfg.rope_theta)
    if isinstance(cache, (PagedKVCache, QuantPagedKVCache)):
        assert tables is not None, "paged decode needs a BlockTables"
        pos_v = pos if per_slot else jnp.broadcast_to(pos, (b,))
        if kind == "local":
            ring = tables.ring_len
            slot_v = pos_v % ring
            kv_len = jnp.minimum(pos_v + 1, ring)
        else:
            slot_v = pos_v
            kv_len = pos_v + 1
        cache = _paged_write_token(cache, tables.table, slot_v, k_new, v_new)
        if use_kernel and _dyn_exact(qk_b) and _dyn_exact(pv_b):
            from repro.kernels.paged_attention import paged_attention_decode

            quant = isinstance(cache, QuantPagedKVCache)
            o = paged_attention_decode(q[:, :, 0], cache.k, cache.v,
                                       tables.table, kv_len,
                                       softcap=cfg.logit_softcap,
                                       k_scale=cache.k_scale if quant else None,
                                       v_scale=cache.v_scale if quant else None,
                                       )[:, :, None]
        else:
            k_log, v_log = _paged_view(cache, tables.table)
            o = _sdpa(q, k_log, v_log, causal=False, window=0, kv_len=kv_len,
                      softcap=cfg.logit_softcap, qk=qk_b, pv=pv_b)
        return dense(p["wo"], _merge_heads(o), sites("o_proj")), cache
    s_cache = cache.k.shape[2]
    # global caches are pre-allocated >= pos+1 (no wrap); local rings wrap
    slot = pos % s_cache if kind == "local" else pos
    if per_slot:
        def _write(c, n, s):
            return jax.lax.dynamic_update_slice(c, n, (0, s, 0))

        k = jax.vmap(_write)(cache.k, k_new.astype(cache.k.dtype), slot)
        v = jax.vmap(_write)(cache.v, v_new.astype(cache.v.dtype), slot)
    else:
        k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, 0, slot, 0))
        v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, 0, slot, 0))
    if kind == "local":
        # ring buffer: every resident entry is within the window; valid count
        kv_len = jnp.minimum(pos + 1, s_cache)
    else:
        kv_len = pos + 1
    if use_kernel and _dyn_exact(qk_b) and _dyn_exact(pv_b):
        from repro.kernels.paged_attention import dense_attention_decode

        o = dense_attention_decode(
            q[:, :, 0], k, v, jnp.broadcast_to(kv_len, (b,)),
            softcap=cfg.logit_softcap,
        )[:, :, None]
    else:
        o = _sdpa(q, k, v, causal=False, window=0, kv_len=kv_len,
                  softcap=cfg.logit_softcap, qk=qk_b, pv=pv_b)
    out = dense(p["wo"], _merge_heads(o), sites("o_proj"))
    return out, KVCache(k, v)


def _paged_write_span(pool: jax.Array, table: jax.Array, start: jax.Array,
                      new: jax.Array) -> jax.Array:
    """Scatter a contiguous position span into the pool.

    pool [n_blocks, kv, bs, hd]; new [B, kv, S, hd] landing at each row's
    logical positions ``start[b] + t`` — ``start`` may point anywhere
    inside a block (the chunked-prefill scheduler resumes mid-block), so
    the write is per token position, not per block.  Positions past the
    table width (packed-prefill overrun into another slot's padding
    region) are redirected to the scratch sink — those positions are
    either overwritten by a later chunk or decode before any read exposes
    them, or never readable at all.
    """
    b, kvh, s, hd = new.shape
    bs = pool.shape[2]
    w = table.shape[1]
    pos = start[:, None] + jnp.arange(s)[None, :]  # [B, S] logical positions
    blk = pos // bs
    pb = jnp.take_along_axis(table, jnp.minimum(blk, w - 1), axis=1)
    pb = jnp.where(blk < w, pb, 0)  # overrun -> scratch
    vals = jnp.moveaxis(new, 1, 2).reshape(b * s, kvh, hd)
    return pool.at[pb.reshape(-1), :, (pos % bs).reshape(-1)].set(
        vals.astype(pool.dtype))


def attn_prefill_paged(
    p,
    x: jax.Array,  # [B, S_suf, D] packed suffixes
    cache: AnyPagedKVCache,
    table: jax.Array,  # [B, W]
    start: jax.Array,  # [B] absolute start of each suffix (any offset)
    cfg: ArchConfig,
    *,
    sites: Union[ComputeConfig, SiteBinding] = EXACT,
    ctx_blocks: int,
    use_kernel: bool = False,
) -> Tuple[jax.Array, AnyPagedKVCache]:
    """Suffix prefill with past: global causal attention over the packed
    suffixes against prefix KV already resident in the pool.

    The serve engine's prefix-cache *and* chunked-prefill path: resident
    positions ``< start[b]`` are reused verbatim (matched prefix blocks,
    or this request's own earlier chunks), only the packed suffix runs
    here.  ``start`` may point anywhere inside a block — prefix matches
    are block-aligned, but a scheduler chunk resumes wherever the last
    chunk stopped.  ``ctx_blocks`` (static) bounds the gathered context
    view; it must cover the longest ``start + S_suf`` in the batch.
    Padded rows write garbage into the writer's own future positions or
    scratch — never into readable positions.
    """
    b, s, _ = x.shape
    sites = as_binding(sites)
    positions = start[:, None] + jnp.arange(s)[None, :]  # [B, S]
    q = _split_heads(dense(p["wq"], x, sites("q_proj")), cfg.n_heads, cfg.head_dim)
    k = _split_heads(dense(p["wk"], x, sites("kv_proj")), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(dense(p["wv"], x, sites("kv_proj")), cfg.n_kv_heads, cfg.head_dim)
    q = shard_act(q, ("batch", "heads", None, None))
    q = apply_rope(q, positions, cfg.rope_pct, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_pct, cfg.rope_theta)
    quant = isinstance(cache, QuantPagedKVCache)
    k_st = kv_quantize(k, cache.k_scale) if quant else k
    v_st = kv_quantize(v, cache.v_scale) if quant else v
    cache = cache._replace(
        k=_paged_write_span(cache.k, table, start, k_st),
        v=_paged_write_span(cache.v, table, start, v_st),
    )
    ctx_tbl = jax.lax.slice(table, (0, 0), (b, ctx_blocks))
    qk_b, pv_b = sites("qk"), sites("pv")
    if use_kernel and _dyn_exact(qk_b) and _dyn_exact(pv_b):
        from repro.kernels.paged_attention import paged_attention_prefill

        o = paged_attention_prefill(q, cache.k, cache.v, ctx_tbl, start,
                                    softcap=cfg.logit_softcap,
                                    k_scale=cache.k_scale if quant else None,
                                    v_scale=cache.v_scale if quant else None)
    else:
        k_log, v_log = _paged_view(cache, ctx_tbl)
        o = _sdpa(q, k_log, v_log, causal=True, window=0, q_offset=start,
                  softcap=cfg.logit_softcap, qk=qk_b, pv=pv_b)
    o = shard_act(o, ("batch", "heads", None, None))
    out = shard_act(dense(p["wo"], _merge_heads(o), sites("o_proj")), ("batch", None, None))
    return out, cache
