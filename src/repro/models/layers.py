"""Shared neural building blocks (pure JAX, functional params-as-pytrees).

All GEMMs route through :func:`dense` -> ``core.astra_matmul`` so the whole
zoo switches between exact / int8 / stochastic ASTRA execution modes —
per GEMM *site*: block-level functions take a
:class:`~repro.core.plan.SiteBinding` (``sites("up")`` names the op in the
shared execution/simulator registry) and still accept a plain
``ComputeConfig`` for uniform legacy behavior.  Parameters are plain nested
dicts; leaf names drive the sharding rules in ``repro.parallel.sharding``
(see that module's table).
"""
from __future__ import annotations

import math
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.astra_layer import BoundSite, ComputeConfig, EXACT, astra_matmul
from repro.core.plan import SiteBinding, as_binding

SiteOrCC = Union[ComputeConfig, BoundSite]


def dense_init(key, d_in: int, d_out: int, bias: bool = False, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p, x: jax.Array, cc: SiteOrCC = EXACT) -> jax.Array:
    y = astra_matmul(x, p["w"], cc)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def norm_init(d: int, kind: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p, x: jax.Array, kind: str, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y.astype(x.dtype)


# ----------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, pct: float, theta: float) -> jax.Array:
    rot_dim = int(head_dim * pct) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, jnp.float32) / rot_dim))


def apply_rope(x: jax.Array, positions: jax.Array, pct: float, theta: float) -> jax.Array:
    """x [B, H, S, D], positions [B, S] (absolute)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, pct, theta)  # [rot/2]
    rot = freqs.shape[0] * 2
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,S,rot/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    y = jnp.stack([y1, y2], axis=-1).reshape(*x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1) if rot < d else y.astype(x.dtype)


# ----------------------------------------------------------------- MLP
def mlp_init(key, cfg: ArchConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": dense_init(k1, cfg.d_model, d_ff), "down": dense_init(k2, d_ff, cfg.d_model)}
    if cfg.act in ("swiglu", "geglu"):
        p["gate"] = dense_init(k3, cfg.d_model, d_ff)
    return p


def mlp_apply(p, x: jax.Array, cfg: ArchConfig,
              sites: Union[ComputeConfig, SiteBinding] = EXACT) -> jax.Array:
    from repro.parallel.sharding import shard_act

    sites = as_binding(sites)
    # the gate GEMM shares the "up" site: the simulator models gated MLPs
    # as one fused d -> 2*d_ff up op
    up = dense(p["up"], x, sites("up"))
    if "gate" in p:
        g = dense(p["gate"], x, sites("up"))
        act = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        h = act * up
    else:
        h = jax.nn.gelu(up)
    h = shard_act(h, ("batch", None, "ffn"))
    return shard_act(dense(p["down"], h, sites("down")), ("batch", None, None))


# ----------------------------------------------------------------- embeddings
def embedding_init(key, cfg: ArchConfig):
    n_emb = max(1, cfg.n_codebooks or 1)
    tables = jax.random.normal(key, (n_emb, cfg.vocab, cfg.d_model), jnp.float32) * 0.02
    p = {"table": tables[0] if n_emb == 1 else tables}
    return p


def embed_tokens(p, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    """tokens [B, S] (or [B, C, S] multi-codebook) -> [B, S, D]."""
    if cfg.n_codebooks:
        # sum of per-codebook embeddings (MusicGen): tokens [B,C,S], table [C,V,D]
        x = sum(p["table"][c][tokens[:, c]] for c in range(cfg.n_codebooks))
        return x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    x = p["table"][tokens]
    return x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)


def head_init(key, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return {}
    n_heads = max(1, cfg.n_codebooks or 1)
    w = jax.random.normal(key, (n_heads, cfg.d_model, cfg.vocab), jnp.float32) / math.sqrt(cfg.d_model)
    return {"w": w[0] if n_heads == 1 else w}


def head_apply(p, emb_p, x: jax.Array, cfg: ArchConfig, cc: SiteOrCC = EXACT) -> jax.Array:
    """x [B, S, D] -> logits [B, S, V] (or [B, S, C, V])."""
    if cfg.tie_embeddings:
        w = emb_p["table"].T  # [D, V]
        return astra_matmul(x, w, cc).astype(jnp.float32)
    w = p["w"]
    if cfg.n_codebooks:
        return jnp.stack([astra_matmul(x, w[c], cc) for c in range(cfg.n_codebooks)], axis=2).astype(jnp.float32)
    return astra_matmul(x, w, cc).astype(jnp.float32)
