"""Composable model zoo: every assigned architecture from one block library."""
from repro.models.model import Model, ModelOptions

__all__ = ["Model", "ModelOptions"]
