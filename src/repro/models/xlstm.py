"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM (arXiv:2405.04517): covariance-style matrix state with exponential
input gate and forget gate.  Two mathematically equivalent forms:

* sequence path — the *quadratic* decay-masked linear-attention form:
      D[t,s] = b_t - b_s + li_s  (s <= t, else -inf),  b = cumsum(logsigmoid(f))
      m_t = max_s D[t,s]
      h_t = sum_s exp(D[t,s] - m_t) (q_t . k_s) v_s
            / max(|sum_s exp(D[t,s] - m_t) (q_t . k_s)|, exp(-m_t))
  (identical to the stabilized recurrence because the running max
  m_t = max(lf_t + m_{t-1}, li_t) telescopes to the row max of D).
* decode path — the stabilized recurrence over (C~, n~, m) carried in the
  serving state; O(1) per token, bounded memory (the reason this arch runs
  the ``long_500k`` shape).

sLSTM: scalar memory with recurrent (per-head block-diagonal) connections —
inherently sequential; implemented as a lax.scan over time.  Under ASTRA
its recurrent part stays electronic (DESIGN.md §Arch-applicability).

Block layouts follow the paper: mLSTM is a pre-up-projection block (2x
expansion, gated); sLSTM is post-up-projection (4/3 GLU FFN after the cell).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.astra_layer import ComputeConfig, EXACT
from repro.core.plan import SiteBinding, as_binding
from repro.models.layers import dense, dense_init, norm_apply, norm_init
from repro.parallel.sharding import shard_act


# ===================================================================== mLSTM
class MLSTMState(NamedTuple):
    c: jax.Array  # [B, H, dk, dv] stabilized matrix memory
    n: jax.Array  # [B, H, dk]
    m: jax.Array  # [B, H] running log max


def mlstm_init(key, cfg: ArchConfig):
    d = cfg.d_model
    e = 2 * d
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, 2 * e),  # [x | gate]
        "w_q": dense_init(ks[1], e, e),
        "w_k": dense_init(ks[2], e, e),
        "w_v": dense_init(ks[3], e, e),
        "w_if": dense_init(ks[4], e, 2 * h),  # input+forget gate per head
        "out_norm": norm_init(e, "rmsnorm"),
        "w_down": dense_init(ks[5], e, d),
    }


def _mlstm_qkvif(p, xe: jax.Array, cfg: ArchConfig, sites: SiteBinding):
    b, s, e = xe.shape
    h = cfg.n_heads
    dh = e // h
    q = dense(p["w_q"], xe, sites("qkv")).reshape(b, s, h, dh).transpose(0, 2, 1, 3) * (dh ** -0.5)
    k = dense(p["w_k"], xe, sites("qkv")).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = dense(p["w_v"], xe, sites("qkv")).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    gif = dense(p["w_if"], xe, sites("gates")).astype(jnp.float32).reshape(b, s, 2, h)
    li = gif[:, :, 0].transpose(0, 2, 1)  # [B, H, S] log input gate (pre-exp)
    lf = jax.nn.log_sigmoid(gif[:, :, 1]).transpose(0, 2, 1)  # [B, H, S]
    return q, k, v, li, lf


def mlstm_seq(
    p, x: jax.Array, cfg: ArchConfig,
    sites: ComputeConfig | SiteBinding = EXACT, return_state: bool = False
) -> Tuple[jax.Array, MLSTMState | None]:
    b, s, d = x.shape
    e = 2 * d
    sites = as_binding(sites)
    up = shard_act(dense(p["w_up"], x, sites("up_proj")), ("batch", None, "ffn"))
    xe, gate = up[..., :e], up[..., e:]
    q, k, v, li, lf = _mlstm_qkvif(p, xe, cfg, sites)
    bcum = jnp.cumsum(lf, axis=-1)  # [B, H, S]
    dmat = bcum[..., :, None] - bcum[..., None, :] + li[..., None, :]  # [B,H,S,S]
    mask = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(mask, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=-1)  # [B, H, S]
    w = jnp.exp(dmat - m[..., None])  # [B,H,S,S]
    scores = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32))
    ws = w * scores
    num = jnp.einsum("bhts,bhsd->bhtd", ws, v.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(ws.sum(-1)), jnp.exp(-m))  # [B,H,S]
    hseq = (num / den[..., None]).astype(x.dtype)  # [B,H,S,dh]
    hmerged = hseq.transpose(0, 2, 1, 3).reshape(b, s, e)
    hmerged = norm_apply(p["out_norm"], hmerged, "rmsnorm", cfg.norm_eps)
    out = dense(p["w_down"], hmerged * jax.nn.silu(gate), sites("down_proj"))
    state = None
    if return_state:
        # fold the whole sequence into the recurrent state for serving
        state = _mlstm_fold_state(q, k, v, li, lf, bcum)
    return out, state


def _mlstm_fold_state(q, k, v, li, lf, bcum) -> MLSTMState:
    bsz, h, s, dh = k.shape
    btot = bcum[..., -1]  # [B, H]
    dvec = btot[..., None] - bcum + li  # weight of each s in final state
    m_s = jnp.max(dvec, axis=-1)  # [B, H]
    wv = jnp.exp(dvec - m_s[..., None])
    c = jnp.einsum("bhs,bhsd,bhse->bhde", wv, k.astype(jnp.float32), v.astype(jnp.float32))
    n = jnp.einsum("bhs,bhsd->bhd", wv, k.astype(jnp.float32))
    return MLSTMState(c, n, m_s)


def mlstm_state_init(cfg: ArchConfig, batch: int) -> MLSTMState:
    e = 2 * cfg.d_model
    h = cfg.n_heads
    dh = e // h
    return MLSTMState(
        jnp.zeros((batch, h, dh, dh), jnp.float32),
        jnp.zeros((batch, h, dh), jnp.float32),
        jnp.full((batch, h), -1e30, jnp.float32),
    )


def mlstm_decode(
    p, x: jax.Array, state: MLSTMState, cfg: ArchConfig,
    sites: ComputeConfig | SiteBinding = EXACT
) -> Tuple[jax.Array, MLSTMState]:
    b, one, d = x.shape
    e = 2 * d
    sites = as_binding(sites)
    up = dense(p["w_up"], x, sites("up_proj"))
    xe, gate = up[..., :e], up[..., e:]
    q, k, v, li, lf = _mlstm_qkvif(p, xe, cfg, sites)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]  # [B, H, dh]
    li, lf = li[..., 0], lf[..., 0]  # [B, H]
    m_new = jnp.maximum(lf + state.m, li)
    alpha = jnp.exp(lf + state.m - m_new)[..., None]
    beta = jnp.exp(li - m_new)[..., None]
    c = alpha[..., None] * state.c + beta[..., None] * (
        k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    )
    n = alpha * state.n + beta * k.astype(jnp.float32)
    num = jnp.einsum("bhde,bhd->bhe", c, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q.astype(jnp.float32))), jnp.exp(-m_new))
    hvec = (num / den[..., None]).reshape(b, 1, e).astype(x.dtype)
    hvec = norm_apply(p["out_norm"], hvec, "rmsnorm", cfg.norm_eps)
    out = dense(p["w_down"], hvec * jax.nn.silu(gate), sites("down_proj"))
    return out, MLSTMState(c, n, m_new)


# ===================================================================== sLSTM
class SLSTMState(NamedTuple):
    c: jax.Array  # [B, H, dh]
    n: jax.Array  # [B, H, dh]
    m: jax.Array  # [B, H, dh]
    h: jax.Array  # [B, H, dh]


def slstm_init(key, cfg: ArchConfig):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    f_up = int(d * 4 / 3)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, bias=True),  # i f z o
        "r_gates": jax.random.normal(ks[1], (4, h, dh, dh), jnp.float32) / math.sqrt(dh),
        "out_norm": norm_init(d, "rmsnorm"),
        "w_up": dense_init(ks[2], d, 2 * f_up),
        "w_down": dense_init(ks[3], f_up, d),
    }


def slstm_state_init(cfg: ArchConfig, batch: int) -> SLSTMState:
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return SLSTMState(z, z, jnp.full_like(z, -1e30), z)


def _slstm_cell(p, wx_t: jax.Array, state: SLSTMState) -> Tuple[SLSTMState, jax.Array]:
    """wx_t: [B, 4, H, dh] pre-computed input contribution at step t."""
    rh = jnp.einsum("ghde,bhd->gbhe", p["r_gates"], state.h)  # [4, B, H, dh]
    pre = wx_t.transpose(1, 0, 2, 3) + rh  # [4, B, H, dh]
    i_raw, f_raw, z_raw, o_raw = pre[0], pre[1], pre[2], pre[3]
    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(lf + state.m, i_raw)
    alpha = jnp.exp(lf + state.m - m_new)
    beta = jnp.exp(i_raw - m_new)
    c = alpha * state.c + beta * jnp.tanh(z_raw)
    n = alpha * state.n + beta
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1e-9)
    return SLSTMState(c, n, m_new, h), h


def slstm_seq(
    p, x: jax.Array, cfg: ArchConfig,
    sites: ComputeConfig | SiteBinding = EXACT, return_state: bool = False
) -> Tuple[jax.Array, SLSTMState | None]:
    b, s, d = x.shape
    hh, dh = cfg.n_heads, d // cfg.n_heads
    sites = as_binding(sites)
    wx = dense(p["w_gates"], x, sites("gates_in")).astype(jnp.float32).reshape(b, s, 4, hh, dh)
    state0 = slstm_state_init(cfg, b)

    def step(st, wx_t):
        st2, h = _slstm_cell(p, wx_t, st)
        return st2, h

    state, hs = jax.lax.scan(step, state0, jnp.moveaxis(wx, 1, 0))
    hseq = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    hseq = norm_apply(p["out_norm"], hseq, "rmsnorm", cfg.norm_eps)
    up = dense(p["w_up"], hseq, sites("up"))
    f = up.shape[-1] // 2
    y = jax.nn.gelu(up[..., :f]) * up[..., f:]
    out = dense(p["w_down"], y, sites("down"))
    return out, (state if return_state else None)


def slstm_decode(
    p, x: jax.Array, state: SLSTMState, cfg: ArchConfig,
    sites: ComputeConfig | SiteBinding = EXACT
) -> Tuple[jax.Array, SLSTMState]:
    b, one, d = x.shape
    hh, dh = cfg.n_heads, d // cfg.n_heads
    sites = as_binding(sites)
    wx = dense(p["w_gates"], x, sites("gates_in")).astype(jnp.float32).reshape(b, 4, hh, dh)
    state2, h = _slstm_cell(p, wx, state)
    hseq = h.reshape(b, 1, d).astype(x.dtype)
    hseq = norm_apply(p["out_norm"], hseq, "rmsnorm", cfg.norm_eps)
    up = dense(p["w_up"], hseq, sites("up"))
    f = up.shape[-1] // 2
    y = jax.nn.gelu(up[..., :f]) * up[..., f:]
    return dense(p["w_down"], y, sites("down")), state2
