"""Model facade: init / loss / train_step / prefill / decode + input specs.

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
model input of a given assignment cell — the dry-run lowers against these
(weak-type-correct, shardable, no device allocation).  Modality frontends
are stubs per the assignment: MusicGen gets the EnCodec token grid,
Llama-Vision gets precomputed patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.plan import ExecutionPlan
from repro.models.transformer import (
    ModelOptions, decode_step, forward, init_decode_state, init_params,
    suffix_forward,
)


def cross_entropy(logits: jax.Array, labels: jax.Array, z_loss: float = 0.0):
    """logits [..., V] fp32, labels [...] int32 with -1 = masked."""
    valid = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0] - logz
    loss = -(ll * valid).sum() / jnp.maximum(valid.sum(), 1.0)
    if z_loss:
        loss = loss + z_loss * ((logz**2) * valid).sum() / jnp.maximum(valid.sum(), 1.0)
    return loss


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    opts: ModelOptions = ModelOptions()

    # --------------------------------------------------------------- plan
    @property
    def plan(self) -> ExecutionPlan:
        return self.opts.plan

    def with_plan(self, plan) -> "Model":
        """Same model under a different ExecutionPlan (any ``from_spec``
        form: plan, preset/mode name, JSON rules, dict)."""
        plan = ExecutionPlan.from_spec(plan)
        return dataclasses.replace(
            self, opts=dataclasses.replace(self.opts, plan=plan, cc=None)
        )

    def calibrate(self, params, batch) -> "Model":
        """PTQ calibration pass: one exact-mode forward over ``batch`` with
        per-site activation observers; returns the model with per-site
        static ``act_scale`` baked into its plan."""
        return self.with_plan(self.plan.calibrate(self, params, batch))

    # ------------------------------------------------------------- params
    def init(self, key) -> Dict[str, Any]:
        return init_params(key, self.cfg)

    def param_shapes(self) -> Dict[str, Any]:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------- train
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        tokens = batch["tokens"]
        logits, aux, _ = forward(
            params, tokens, self.cfg, self.opts, vision_embeds=batch.get("vision_embeds")
        )
        if self.cfg.n_codebooks:
            # tokens [B, C, S], logits [B, S, C, V]: shift along S per codebook
            labels = tokens[:, :, 1:].transpose(0, 2, 1)  # [B, S-1, C]
            loss = cross_entropy(logits[:, :-1], labels, self.opts.z_loss)
        else:
            loss = cross_entropy(logits[:, :-1], tokens[:, 1:], self.opts.z_loss)
        loss = loss + aux
        return loss, {"loss": loss, "aux": aux}

    # ------------------------------------------------------------- serve
    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Full-sequence pass emitting serving states.  ``max_len`` pads the
        KV caches to the serve engine's pre-allocated slot length."""
        logits, _, states = forward(
            params, batch["tokens"], self.cfg, self.opts,
            vision_embeds=batch.get("vision_embeds"), return_states=True,
            max_len=max_len,
        )
        return logits, states

    def decode(self, params, token, states, pos, block_tables=None):
        return decode_step(params, token, states, pos, self.cfg, self.opts,
                           block_tables=block_tables)

    def prefill_suffix(self, params, tokens, states, table, start, ctx_blocks: int):
        """Prefix-aware packed prefill against the paged KV pool (pure
        global-attention stacks; docs/SERVING.md).  Returns full suffix
        logits plus the updated pooled states."""
        return suffix_forward(params, tokens, self.cfg, self.opts, states,
                              table, start, ctx_blocks)

    def init_decode_state(self, batch: int, max_len: int, paged=None):
        """``paged=(n_blocks, block_size)`` builds the pooled layout for
        attn/local caches (see ``transformer.init_decode_state``).  With
        ``opts.kv_quant="int8"`` the pools are int8 blocks carrying the
        plan's calibrated per-KV-head scales."""
        return init_decode_state(self.cfg, batch, max_len, paged,
                                 kv_quant=self.opts.kv_quant,
                                 plan=self.opts.plan)


# ---------------------------------------------------------------- specs
def _tok_spec(cfg: ArchConfig, batch: int, seq: int):
    if cfg.n_codebooks:
        return jax.ShapeDtypeStruct((batch, cfg.n_codebooks, seq), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Model-input stand-ins for one assignment cell.

    train/prefill: {"tokens", ["vision_embeds"]}.
    decode: {"token" (one step), "states" (KV/recurrent state of seq_len),
             "pos"} — lowered against ``serve_step``.
    """
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {"tokens": _tok_spec(cfg, shape.global_batch, shape.seq_len)}
        if cfg.vision_tokens:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.vision_tokens, cfg.d_model), dtype
            )
        return specs
    # decode
    states = jax.eval_shape(lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len))
    specs = {
        "token": _tok_spec(cfg, shape.global_batch, 1),
        "states": states,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return specs
