"""Mixture-of-Experts FFN: top-k router + capacity-based dense dispatch.

TPU/GSPMD-idiomatic MoE (Switch/MaxText style): tokens are dispatched into
an [E, capacity, D] buffer with one-hot combine weights, experts run as one
batched einsum over the expert axis (shardable over the "model" mesh axis =
expert parallelism), results are combined back.  Capacity factor bounds the
buffer; overflowing tokens are dropped from the MoE path (they keep the
residual), standard practice for inference-grade routing.

Load-balance auxiliary loss follows Switch Transformer (mean gate fraction
x mean dispatch fraction x E).
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.astra_layer import (
    ComputeConfig, EXACT, astra_batched_matmul, astra_matmul,
)
from repro.core.plan import SiteBinding, as_binding
from repro.models.layers import dense_init
from repro.parallel.sharding import shard_act


def moe_init(key, cfg: ArchConfig):
    m = cfg.moe
    kr, ku, kg, kd = jax.random.split(key, 4)
    d, e, f = cfg.d_model, m.n_experts, m.d_expert
    scale_in = d ** -0.5
    p = {
        "router": dense_init(kr, d, e, scale=0.02),
        "w_up": jax.random.normal(ku, (e, d, f), jnp.float32) * scale_in,
        "w_down": jax.random.normal(kd, (e, f, d), jnp.float32) * (f ** -0.5),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(kg, (e, d, f), jnp.float32) * scale_in
    return p


MOE_GROUP = 512  # tokens per dispatch group (perf: dispatch cost ~ cf*k*g^2*D)


def moe_apply(
    p,
    x: jax.Array,  # [B, S, D]
    cfg: ArchConfig,
    sites: Union[ComputeConfig, SiteBinding] = EXACT,
    capacity_factor: float = 1.25,
    full_capacity: bool = False,
    group_size: int = MOE_GROUP,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,D], aux load-balance loss scalar).

    **Grouped dispatch** (perf-critical): a single one-hot dispatch einsum
    over all T tokens costs 2*T*E*C*D with C ~ cf*k*T/E, i.e. O(T^2) — at
    T = 1M train tokens it dwarfs the expert FLOPs ~500x (measured 0.002
    useful-compute ratio in the dry-run).  Dispatching within groups of
    ``g`` tokens cuts it to 2*cf*k*g*T*D: overhead vs expert compute =
    cf*g/(3*d_expert) — ~28% at g=512, d_expert=768.  Groups follow the
    batch sharding (G over "data", experts over "model"), so GSPMD lowers
    the group->expert exchange to the EP all-to-all.

    ``full_capacity=True`` sizes buffers so no token can ever drop
    (capacity = g) — used on the decode path where T is small and routing
    must match the prefill pass exactly.
    """
    m = cfg.moe
    b, s, d = x.shape
    sites = as_binding(sites)
    t = b * s
    g = min(group_size, t)
    while t % g:  # groups must tile the token stream exactly
        g -= 1
    n_groups = t // g
    xt = x.reshape(n_groups, g, d)
    logits = astra_matmul(xt.astype(jnp.float32), p["router"]["w"], sites("router"))
    probs = jax.nn.softmax(logits, axis=-1)  # [G, g, E]
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)  # [G, g, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # full capacity: every token can land all top_k choices in one expert
    capacity = g * m.top_k if full_capacity else max(
        m.top_k, int(capacity_factor * g * m.top_k / m.n_experts)
    )
    # position of each (token, k) slot within its expert's per-group buffer
    onehot = jax.nn.one_hot(expert_idx, m.n_experts, dtype=jnp.int32)  # [G, g, k, E]
    flat = onehot.reshape(n_groups, g * m.top_k, m.n_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - 1) * flat  # [G, g*k, E]
    pos = pos_in_expert.max(-1).reshape(n_groups, g, m.top_k)  # [G, g, k]
    keep = pos < capacity

    # dispatch tensor: one-hot expert x one-hot slot -> [G, g, k, E, C]
    e_oh = jax.nn.one_hot(expert_idx, m.n_experts, dtype=xt.dtype)
    c_oh = jax.nn.one_hot(pos, capacity, dtype=xt.dtype)
    disp = e_oh[..., :, None] * c_oh[..., None, :]
    disp = disp * keep[..., None, None].astype(xt.dtype)
    disp_te_c = disp.sum(2)  # [G, g, E, C]
    expert_in = jnp.einsum("gtec,gtd->gecd", disp_te_c, xt)  # [G, E, C, D]
    expert_in = shard_act(expert_in, ("batch", "experts", None, None))

    # per-expert GEMMs: [G,E,C,D] x [E,D,F] with the expert axis batched —
    # exact mode stays an einsum-equivalent matmul; quantized modes give
    # each expert its own scales (astra_batched_matmul).  The gate shares
    # the expert_up site (the simulator fuses gate+up into one 2*d_expert op).
    up = astra_batched_matmul(expert_in, p["w_up"], sites("expert_up"))
    if "w_gate" in p:
        gg = astra_batched_matmul(expert_in, p["w_gate"], sites("expert_up"))
        act = jax.nn.silu(gg) if cfg.act == "swiglu" else jax.nn.gelu(gg)
        h = act * up
    else:
        h = jax.nn.gelu(up)
    expert_out = shard_act(
        astra_batched_matmul(h, p["w_down"], sites("expert_down")),
        ("batch", "experts", None, None),
    )  # [G, E, C, D]

    combine = (gate_vals[..., None, None].astype(xt.dtype) * disp).sum(2)  # [G, g, E, C]
    out = jnp.einsum("gtec,gecd->gtd", combine, expert_out).reshape(b, s, d)

    # Switch-style load-balance loss
    me = probs.mean((0, 1))  # [E] mean router prob
    ce = (onehot.sum(2) > 0).astype(jnp.float32).mean((0, 1))  # [E] dispatch fraction
    aux = m.n_experts * jnp.sum(me * ce) * cfg.moe.load_balance_coef
    return out.astype(x.dtype), aux
