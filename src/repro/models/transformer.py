"""The composable decoder stack: pattern units, scan-over-layers, serving state.

A model is ``block_pattern`` repeated ``n_units`` times (stacked params,
executed under ``lax.scan`` so the HLO stays one-unit-sized regardless of
depth) plus an unrolled remainder (e.g. RecurrentGemma's 26 = 8x3 + 2).
Every block kind exposes a sequence path (training / prefill, optionally
emitting its serving state) and a decode path (one token + state).

Serving state is a pytree mirroring the parameter stacking:
``{"units": {"slot<i>": stacked_state}, "rem": [state...]}``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.astra_layer import ComputeConfig, EXACT
from repro.core.plan import ExecutionPlan
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    embed_tokens, embedding_init, head_apply, head_init,
    mlp_apply, mlp_init, norm_apply, norm_init,
)


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    """Execution options.  GEMM modes are governed by ``plan`` (an
    :class:`~repro.core.plan.ExecutionPlan`, or any ``from_spec`` form:
    preset name, mode string, JSON rules, dict).

    ``cc`` is the DEPRECATED one-release shim for the old global-mode API:
    ``ModelOptions(cc=ComputeConfig("int8"))`` lowers to
    ``ExecutionPlan.uniform(cc)`` (same numerics as before — weight GEMMs
    quantized, dynamic qk/pv exact) and is then normalized to ``None`` so
    equal plans hash/compare equal regardless of which spelling built them.
    """

    plan: Optional[Union[ExecutionPlan, str, dict, ComputeConfig]] = None
    cc: Optional[ComputeConfig] = None  # DEPRECATED -> uniform plan
    # naive = jnp einsum everywhere; flash = Pallas attention kernels
    # (interpret on CPU): flash_attention on the sequence path, the
    # gather-free paged_attention kernels on decode and paged suffix
    # prefill.  Kernels cover exact qk/pv only — quantized dynamic sites
    # fall back to the astra-batched path per site.
    attn_impl: str = "naive"
    # KV *storage* quantization for the paged pool: "none" keeps blocks in
    # model dtype; "int8" stores them as symmetric int8 against calibrated
    # static per-KV-head scales (plan.kv_scales, baked by Model.calibrate).
    # Paged layouts only — the serve engine refuses dense + kv_quant.
    kv_quant: str = "none"
    use_rglru_kernel: bool = False
    remat: bool = True
    capacity_factor: float = 1.25
    z_loss: float = 1e-4

    ATTN_IMPLS = ("naive", "flash")
    KV_QUANTS = ("none", "int8")

    def __post_init__(self):
        if self.attn_impl not in self.ATTN_IMPLS:
            raise ValueError(
                f"attn_impl={self.attn_impl!r} unknown; valid: "
                f"{', '.join(self.ATTN_IMPLS)}"
            )
        if self.kv_quant not in self.KV_QUANTS:
            raise ValueError(
                f"kv_quant={self.kv_quant!r} unknown; valid: "
                f"{', '.join(self.KV_QUANTS)}"
            )
        plan = self.plan
        if plan is None:
            plan = ExecutionPlan.uniform(self.cc if self.cc is not None else EXACT)
        elif not isinstance(plan, ExecutionPlan):
            plan = ExecutionPlan.from_spec(plan)
        object.__setattr__(self, "plan", plan)
        object.__setattr__(self, "cc", None)  # normalized: plan is the truth


# ------------------------------------------------------------------ blocks
def _has_mlp(cfg: ArchConfig, kind: str) -> bool:
    return kind in ("attn", "local", "xattn", "rglru") and (cfg.d_ff > 0 or cfg.moe is not None)


def block_init(key, cfg: ArchConfig, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"pre_norm": norm_init(cfg.d_model, cfg.norm)}
    if kind in ("attn", "local", "xattn"):
        p["core"] = attn.attn_init(k1, cfg, cross=(kind == "xattn"))
    elif kind == "rglru":
        p["core"] = rglru_mod.rglru_init(k1, cfg)
    elif kind == "mlstm":
        p["core"] = xlstm_mod.mlstm_init(k1, cfg)
    elif kind == "slstm":
        p["core"] = xlstm_mod.slstm_init(k1, cfg)
    if _has_mlp(cfg, kind):
        p["post_norm"] = norm_init(cfg.d_model, cfg.norm)
        p["mlp"] = moe_mod.moe_init(k2, cfg) if cfg.moe is not None else mlp_init(k2, cfg)
    return p


def block_apply_seq(
    p, x, cfg: ArchConfig, kind: str, opts: ModelOptions, layers: Tuple[int, ...],
    vision_embeds=None, return_state: bool = False, max_len: Optional[int] = None,
):
    """Returns (x, state, aux).  ``layers`` holds the concrete layer
    indices this trace stands for (one index for unrolled remainder
    layers; every unit's index for a scanned pattern slot) — they form the
    ``L{li}.{kind}.*`` site group the plan resolves."""
    sites = opts.plan.binding(kind, layers)
    h = norm_apply(p["pre_norm"], x, cfg.norm, cfg.norm_eps)
    state = None
    if kind in ("attn", "local", "xattn"):
        out, cache = attn.attn_seq(
            p["core"], h, cfg, kind=kind, sites=sites,
            use_flash=(opts.attn_impl == "flash"),
            kv_src=vision_embeds, return_cache=return_state, max_len=max_len,
        )
        state = cache
    elif kind == "rglru":
        out, state = rglru_mod.rglru_seq(p["core"], h, cfg, sites, opts.use_rglru_kernel, return_state)
    elif kind == "mlstm":
        out, state = xlstm_mod.mlstm_seq(p["core"], h, cfg, sites, return_state)
    elif kind == "slstm":
        out, state = xlstm_mod.slstm_seq(p["core"], h, cfg, sites, return_state)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if _has_mlp(cfg, kind):
        h2 = norm_apply(p["post_norm"], x, cfg.norm, cfg.norm_eps)
        if cfg.moe is not None:
            mo, aux = moe_mod.moe_apply(p["mlp"], h2, cfg, sites, opts.capacity_factor)
        else:
            mo = mlp_apply(p["mlp"], h2, cfg, sites)
        x = x + mo
    if return_state and state is None:
        state = jnp.zeros((x.shape[0],), jnp.float32)  # placeholder leaf
    return x, state, aux


def block_apply_decode(p, x, state, pos, cfg: ArchConfig, kind: str,
                       opts: ModelOptions, layers: Tuple[int, ...],
                       block_tables=None):
    sites = opts.plan.binding(kind, layers)
    h = norm_apply(p["pre_norm"], x, cfg.norm, cfg.norm_eps)
    if kind in ("attn", "local", "xattn"):
        out, state = attn.attn_decode(p["core"], h, state, pos, cfg, kind=kind,
                                      sites=sites, tables=block_tables,
                                      use_kernel=(opts.attn_impl == "flash"))
    elif kind == "rglru":
        out, state = rglru_mod.rglru_decode(p["core"], h, state, cfg, sites)
    elif kind == "mlstm":
        out, state = xlstm_mod.mlstm_decode(p["core"], h, state, cfg, sites)
    elif kind == "slstm":
        out, state = xlstm_mod.slstm_decode(p["core"], h, state, cfg, sites)
    x = x + out
    if _has_mlp(cfg, kind):
        h2 = norm_apply(p["post_norm"], x, cfg.norm, cfg.norm_eps)
        if cfg.moe is not None:
            mo, _ = moe_mod.moe_apply(p["mlp"], h2, cfg, sites, full_capacity=True)
        else:
            mo = mlp_apply(p["mlp"], h2, cfg, sites)
        x = x + mo
    return x, state


def block_state_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                     paged: Optional[Tuple[int, int]] = None,
                     kv_quant: str = "none",
                     plan: Optional[ExecutionPlan] = None,
                     layers: Tuple[int, ...] = ()):
    if kind in ("attn", "local") and paged is not None:
        n_blocks, block_size = paged
        if kv_quant == "int8":
            if plan is None:
                raise ValueError("kv_quant='int8' needs a calibrated plan")
            k_scale = plan.kv_group_scale(tuple(f"L{li}.kv.k" for li in layers))
            v_scale = plan.kv_group_scale(tuple(f"L{li}.kv.v" for li in layers))
            return attn.init_paged_quant_cache(  # repro-lint: disable=determinism-gates -- allocation dispatch only; ServeEngine.__init__ runs kv_quant_reject_reason before any engine reaches this path
                cfg, n_blocks, block_size, k_scale, v_scale)
        return attn.init_paged_cache(cfg, n_blocks, block_size)
    if kind in ("attn", "local", "xattn"):
        return attn.init_cache(cfg, kind, batch, max_len)
    if kind == "rglru":
        return rglru_mod.RGLRUState(
            jnp.zeros((batch, cfg.d_rnn), jnp.float32),
            jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), jnp.float32),
        )
    if kind == "mlstm":
        return xlstm_mod.mlstm_state_init(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.slstm_state_init(cfg, batch)
    raise ValueError(kind)


# ------------------------------------------------------------------ stack
def init_params(key, cfg: ArchConfig):
    keys = jax.random.split(key, 4)
    pattern = cfg.block_pattern
    n_units = cfg.n_pattern_units
    params: Dict[str, Any] = {
        "embedding": embedding_init(keys[0], cfg),
        "head": head_init(keys[1], cfg),
        "final_norm": norm_init(cfg.d_model, cfg.norm),
    }
    if n_units:
        unit_keys = jax.random.split(keys[2], n_units)
        units = {}
        for si, kind in enumerate(pattern):
            slot_keys = jax.vmap(lambda k, i=si: jax.random.fold_in(k, i))(unit_keys)
            units[f"slot{si}"] = jax.vmap(lambda k, kk=kind: block_init(k, cfg, kk))(slot_keys)
        params["units"] = units
    rem_kinds = cfg.layer_kinds[n_units * len(pattern):]
    if rem_kinds:
        rkeys = jax.random.split(keys[3], len(rem_kinds))
        params["rem"] = [block_init(rkeys[i], cfg, k) for i, k in enumerate(rem_kinds)]
    return params


def _slot_layers(cfg: ArchConfig, si: int) -> Tuple[int, ...]:
    """Concrete layer indices pattern slot ``si`` stands for across the
    scanned units (the slot's GEMM sites form one plan-resolution group)."""
    P = len(cfg.block_pattern)
    return tuple(u * P + si for u in range(cfg.n_pattern_units))


def _unit_seq(cfg, opts, vision_embeds, return_state, max_len=None):
    pattern = cfg.block_pattern

    def fn(x, unit_params):
        states = {}
        aux = jnp.zeros((), jnp.float32)
        for si, kind in enumerate(pattern):
            x, st, a = block_apply_seq(
                unit_params[f"slot{si}"], x, cfg, kind, opts, _slot_layers(cfg, si),
                vision_embeds=vision_embeds, return_state=return_state, max_len=max_len,
            )
            aux += a
            if return_state:
                states[f"slot{si}"] = st
        return x, (states, aux) if return_state else aux

    return fn


def forward(
    params, tokens, cfg: ArchConfig, opts: ModelOptions,
    vision_embeds=None, return_states: bool = False, max_len: Optional[int] = None,
):
    """Full-sequence pass.  Returns (logits, aux, states|None)."""
    from repro.parallel.sharding import shard_act

    x = shard_act(embed_tokens(params["embedding"], tokens, cfg), ("batch", None, None))
    aux_total = jnp.zeros((), jnp.float32)
    states: Dict[str, Any] = {}
    if "units" in params:
        fn = _unit_seq(cfg, opts, vision_embeds, return_states, max_len)
        if opts.remat:
            fn = jax.checkpoint(fn)
        x, ys = jax.lax.scan(fn, x, params["units"])
        if return_states:
            states["units"], aux_seq = ys
            aux_total += aux_seq.sum()
        else:
            aux_total += ys.sum()
    if "rem" in params:
        rem_base = cfg.n_pattern_units * len(cfg.block_pattern)
        rem_kinds = cfg.layer_kinds[rem_base:]
        rem_states = []
        for i, (p_i, kind) in enumerate(zip(params["rem"], rem_kinds)):
            x, st, a = block_apply_seq(
                p_i, x, cfg, kind, opts, (rem_base + i,), vision_embeds=vision_embeds,
                return_state=return_states, max_len=max_len,
            )
            aux_total += a
            rem_states.append(st)
        if return_states:
            states["rem"] = rem_states
    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = head_apply(params["head"], params["embedding"], x, cfg,
                        opts.plan.site("lm_head"))
    return logits, aux_total, (states if return_states else None)


def decode_step(params, token, states, pos, cfg: ArchConfig, opts: ModelOptions,
                block_tables=None):
    """One serving step.  token [B,1] (or [B,C,1] multi-codebook) -> logits.

    ``block_tables`` (an :class:`attn.BlockTables`, optional) routes the
    attn/local cache reads and writes through the paged pool."""
    x = embed_tokens(params["embedding"], token, cfg)
    if "units" in params:
        pattern = cfg.block_pattern

        def fn(x, xs):
            unit_params, unit_states = xs
            new_states = {}
            for si, kind in enumerate(pattern):
                x, st = block_apply_decode(
                    unit_params[f"slot{si}"], x, unit_states[f"slot{si}"], pos,
                    cfg, kind, opts, _slot_layers(cfg, si), block_tables
                )
                new_states[f"slot{si}"] = st
            return x, new_states

        x, new_unit_states = jax.lax.scan(fn, x, (params["units"], states["units"]))
        states = dict(states)
        states["units"] = new_unit_states
    if "rem" in params:
        rem_base = cfg.n_pattern_units * len(cfg.block_pattern)
        rem_kinds = cfg.layer_kinds[rem_base:]
        new_rem = []
        for i, (p_i, st, kind) in enumerate(zip(params["rem"], states["rem"], rem_kinds)):
            x, st2 = block_apply_decode(p_i, x, st, pos, cfg, kind, opts,
                                        (rem_base + i,), block_tables)
            new_rem.append(st2)
        states = dict(states)
        states["rem"] = new_rem
    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = head_apply(params["head"], params["embedding"], x, cfg,
                        opts.plan.site("lm_head"))
    return logits, states


def _block_apply_suffix(p, x, state, table, start, cfg: ArchConfig,
                        opts: ModelOptions, layers: Tuple[int, ...],
                        ctx_blocks: int):
    """One pure-attention block over packed suffixes with pooled past KV."""
    sites = opts.plan.binding("attn", layers)
    h = norm_apply(p["pre_norm"], x, cfg.norm, cfg.norm_eps)
    out, state = attn.attn_prefill_paged(
        p["core"], h, state, table, start, cfg, sites=sites, ctx_blocks=ctx_blocks,
        use_kernel=(opts.attn_impl == "flash"),
    )
    x = x + out
    if _has_mlp(cfg, "attn"):
        h2 = norm_apply(p["post_norm"], x, cfg.norm, cfg.norm_eps)
        if cfg.moe is not None:
            mo, _ = moe_mod.moe_apply(p["mlp"], h2, cfg, sites, opts.capacity_factor)
        else:
            mo = mlp_apply(p["mlp"], h2, cfg, sites)
        x = x + mo
    return x, state


def suffix_forward(params, tokens, cfg: ArchConfig, opts: ModelOptions,
                   states, table, start, ctx_blocks: int):
    """Prefix-aware packed prefill for pure global-attention stacks.

    Runs the unmatched suffixes (``tokens [B, S_suf]``, right-padded) in
    one parallel pass against prefix KV already resident in the paged
    pool, writing the suffix KV into each slot's blocks.  This is the
    serve engine's prefix-cache admission path; a cold request is just
    ``start == 0``.  Returns (logits ``[B, S_suf, V]``, new states).
    """
    if any(k != "attn" for k in cfg.layer_kinds):
        raise ValueError(
            f"suffix_forward needs a pure global-attention stack, got "
            f"{set(cfg.layer_kinds)}; recurrent/windowed/cross states cannot "
            "be reconstructed from paged prefix blocks"
        )
    from repro.parallel.sharding import shard_act

    x = shard_act(embed_tokens(params["embedding"], tokens, cfg), ("batch", None, None))
    if "units" in params:
        pattern = cfg.block_pattern

        def fn(x, xs):
            unit_params, unit_states = xs
            new_states = {}
            for si, _kind in enumerate(pattern):
                x, st = _block_apply_suffix(
                    unit_params[f"slot{si}"], x, unit_states[f"slot{si}"],
                    table, start, cfg, opts, _slot_layers(cfg, si), ctx_blocks
                )
                new_states[f"slot{si}"] = st
            return x, new_states

        x, new_unit_states = jax.lax.scan(fn, x, (params["units"], states["units"]))
        states = dict(states)
        states["units"] = new_unit_states
    if "rem" in params:
        rem_base = cfg.n_pattern_units * len(cfg.block_pattern)
        new_rem = []
        for i, (p_i, st) in enumerate(zip(params["rem"], states["rem"])):
            x, st2 = _block_apply_suffix(p_i, x, st, table, start, cfg, opts,
                                         (rem_base + i,), ctx_blocks)
            new_rem.append(st2)
        states = dict(states)
        states["rem"] = new_rem
    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = head_apply(params["head"], params["embedding"], x, cfg,
                        opts.plan.site("lm_head"))
    return logits, states


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      paged: Optional[Tuple[int, int]] = None,
                      kv_quant: str = "none",
                      plan: Optional[ExecutionPlan] = None):
    """Zeroed serving state (the dry-run's decode input spec).

    ``paged = (n_blocks, block_size)`` swaps the attn/local caches for
    shared block pools (``PagedKVCache``, no batch axis — the block table
    carries slot identity); recurrent and xattn states stay dense-slotted.
    ``kv_quant="int8"`` makes the paged pools int8 with per-KV-head scales
    taken from ``plan.kv_scales`` (layers sharing a scanned trace share one
    calibration tap, so the group scale is exact for them).
    """
    pattern = cfg.block_pattern
    n_units = cfg.n_pattern_units
    states: Dict[str, Any] = {}
    if n_units:
        units = {}
        for si, kind in enumerate(pattern):
            one = block_state_init(cfg, kind, batch, max_len, paged,
                                   kv_quant, plan, _slot_layers(cfg, si))
            units[f"slot{si}"] = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_units, *a.shape)), one)
        states["units"] = units
    rem_base = n_units * len(pattern)
    rem_kinds = cfg.layer_kinds[rem_base:]
    if rem_kinds:
        states["rem"] = [
            block_state_init(cfg, k, batch, max_len, paged,
                             kv_quant, plan, (rem_base + i,))
            for i, k in enumerate(rem_kinds)
        ]
    return states
