"""End-to-end training driver: data -> model -> AdamW -> checkpoint/restart.

The CPU-runnable face of the same stack the dry-run lowers for 512 chips:
identical step function, sharding rules, and checkpoint format — only the
mesh differs (host mesh here, ``make_production_mesh`` on the pod).

Fault tolerance is on by default: atomic async checkpoints every
``--ckpt-every`` steps, automatic resume from the newest checkpoint, and an
optional injected fault schedule (``--fail-at 12,27``) to demonstrate
recovery.  Determinism: the data pipeline is step-addressable, so a resumed
run reproduces the fault-free loss trajectory bit-for-bit.

Usage (tiny model, a few hundred steps on CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import DataConfig, Prefetcher, SyntheticLMDataset
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.parallel.sharding import activation_mesh, batch_specs, param_specs
from repro.runtime import FaultInjector, run_with_restarts


def build_train_step(model: Model, ocfg: AdamWConfig, total_steps: int, warmup: int):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch)[0]

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr_scale = cosine_schedule(opt_state["step"], warmup, total_steps)
        params2, opt2, stats = adamw_update(params, grads, opt_state, ocfg, lr_scale)
        return params2, opt2, {"loss": loss, **stats}

    return train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", default="", help="comma-separated steps to inject faults")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--prefetch", action="store_true",
                    help="background data prefetch w/ straggler deadline+backup")
    ap.add_argument("--prefetch-timeout", type=float, default=30.0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    model = Model(cfg, ModelOptions())
    ocfg = AdamWConfig(lr=args.lr)

    dcfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=args.seed,
        n_codebooks=cfg.n_codebooks, vision_tokens=cfg.vision_tokens, d_model=cfg.d_model,
    )
    dataset = SyntheticLMDataset(dcfg)
    prefetcher = Prefetcher(dataset, timeout_s=args.prefetch_timeout).start() if args.prefetch else None
    injector = FaultInjector(int(s) for s in args.fail_at.split(",") if s)

    param_shapes = model.param_shapes()
    p_shard = param_specs(param_shapes, mesh)
    opt_shapes = jax.eval_shape(adamw_init, param_shapes)
    o_shard = {
        "m": param_specs(opt_shapes["m"], mesh),
        "v": param_specs(opt_shapes["v"], mesh),
        "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }
    step_fn_inner = build_train_step(model, ocfg, args.steps, args.warmup)
    jit_step = jax.jit(
        step_fn_inner,
        in_shardings=((p_shard, o_shard, None)),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1),
    )

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    def init_state():
        with mesh, activation_mesh(mesh):
            params = jax.jit(model.init, out_shardings=p_shard)(jax.random.PRNGKey(args.seed))
            opt = adamw_init(params)
        return {"params": params, "opt": opt}

    t_last = [time.time()]

    def step_fn(state, step):
        injector.check(step)
        batch = prefetcher.get(step) if prefetcher else dataset.batch_at(step)
        b_shard = batch_specs(batch, mesh)
        batch = jax.tree.map(lambda a, s: jax.device_put(a, s), batch, b_shard)
        with mesh, activation_mesh(mesh):
            params, opt, metrics = jit_step(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt}, metrics

    def on_metrics(step, m):
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t_last[0]
            t_last[0] = time.time()
            print(f"step {step:5d}  loss {m['loss']:.4f}  gnorm {m.get('grad_norm', 0):.3f}  "
                  f"({dt:.2f}s)", flush=True)

    summary = run_with_restarts(
        init_state=init_state, step_fn=step_fn, n_steps=args.steps,
        ckpt_manager=mgr, ckpt_every=args.ckpt_every, on_metrics=on_metrics,
    )
    if mgr:
        mgr.save(args.steps - 1, summary["state"], metadata={"final": True})
        mgr.wait()
    if prefetcher:
        prefetcher.stop()
        if prefetcher.substituted_steps:
            print(f"straggler substitutions at steps {prefetcher.substituted_steps}")
    losses = [m["loss"] for m in summary["metrics"].values()]
    print(f"done: {len(losses)} steps, restarts={summary['restarts']}, "
          f"first-loss {losses[0]:.4f} last-loss {losses[-1]:.4f}, wall {summary['wall_s']:.1f}s")
    return summary


if __name__ == "__main__":
    main()
