"""Shared CLI flag surface for the serving stack.

One module owns three things the CLIs and the linter must agree on:

* the **registry** — ``FIELD_FLAGS`` maps every CLI-reachable config
  dataclass field (``ServeConfig`` / ``FrontendConfig`` /
  ``ModelOptions``) to its flag, and ``INTERNAL_FIELDS`` records, with a
  reason, the fields deliberately *not* exposed.  The ``config-surface``
  checker (``repro.analysis``) cross-references both against the actual
  dataclass definitions and the ``add_argument`` calls below, so a field
  added without a flag (or a flag whose field was renamed) fails lint;
* :func:`add_serve_flags` / :func:`validate_serve_flags` — the engine,
  plan, paged-KV, and traffic flags themselves, used by
  ``launch/serve.py`` (validation at the CLI, not deep inside the
  engine);
* :func:`check_choices` — reject unknown names in comma-list flags
  loudly (``benchmarks/run.py --only`` used to silently skip typos).
"""
from __future__ import annotations

import argparse
from typing import Iterable, Sequence

from repro.core.astra_layer import MODES
from repro.core.plan import PRESET_PLANS
from repro.models.transformer import ModelOptions

# ---------------------------------------------------------------- registry
# "Cls.field" -> the flag that reaches it.  Checked by config-surface.
FIELD_FLAGS = {
    "ServeConfig.max_slots": "--max-slots",
    "ServeConfig.chunk_steps": "--chunk-steps",
    "ServeConfig.sampler": "--temperature",  # (+ --top-k, same SamplerConfig)
    "ServeConfig.seed": "--seed",
    "ServeConfig.kv_block_size": "--kv-block-size",
    "ServeConfig.kv_pool_blocks": "--kv-pool-blocks",
    "ServeConfig.prefix_cache": "--no-prefix-cache",
    "ServeConfig.prefill_chunk_tokens": "--prefill-chunk-tokens",
    "ServeConfig.attn_impl": "--attn-impl",
    "ServeConfig.kv_quant": "--kv-quant",
    "ServeConfig.degraded_mode": "--no-degraded-mode",
    "FrontendConfig.max_queue_depth": "--max-queue",
    "FrontendConfig.queue_timeout_s": "--queue-timeout",
    "FrontendConfig.max_concurrency": "--max-concurrency",
    "FrontendConfig.default_deadline_s": "--deadline",
    "FrontendConfig.max_retries": "--max-retries",
    "FrontendConfig.retry_backoff_s": "--retry-backoff",
    "ModelOptions.plan": "--plan",
    "ModelOptions.attn_impl": "--attn-impl",
    "ModelOptions.kv_quant": "--kv-quant",
}
# "Cls.field" -> why it is deliberately not CLI-reachable.
INTERNAL_FIELDS = {
    "ServeConfig.max_len": "derived per run from prompt lengths + --gen "
                           "(or the trace's max length), never set directly",
    "ServeConfig.astra_accounting": "always on in the serving CLI; only "
                                    "unit tests opt out of the simulator",
    "ModelOptions.cc": "deprecated uniform-mode alias; --plan/--mode "
                       "construct an ExecutionPlan instead",
    "ModelOptions.use_rglru_kernel": "kernel-selection toggle for the "
                                     "parity tests; serving always uses "
                                     "the default path",
    "ModelOptions.remat": "training-memory knob; inference never remats",
    "ModelOptions.capacity_factor": "MoE train-time capacity; serving "
                                    "uses the checkpoint's routing as-is",
    "ModelOptions.z_loss": "training-only auxiliary loss weight",
}


def check_choices(ap: argparse.ArgumentParser, flag: str,
                  values: Iterable[str], valid: Sequence[str]) -> None:
    """``ap.error`` on any value outside ``valid`` — comma-list flags must
    reject typos loudly, not silently run nothing."""
    unknown = sorted(set(values) - set(valid))
    if unknown:
        ap.error(f"{flag}: unknown name(s): {', '.join(unknown)}; "
                 f"valid: {', '.join(valid)}")


# ------------------------------------------------------------------- flags
def add_serve_flags(ap: argparse.ArgumentParser) -> None:
    """Register the engine / plan / paged-KV / traffic flag surface."""
    ap.add_argument("--mode", default="int8", choices=list(MODES),
                    help="uniform execution mode (shorthand for --plan <mode>)")
    ap.add_argument("--plan", default="",
                    help="per-site execution plan: preset "
                         f"({', '.join(sorted(PRESET_PLANS))}), uniform mode, "
                         "or JSON glob rules; overrides --mode")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--chunk-steps", type=int, default=8,
                    help="fused decode steps per dispatch")
    ap.add_argument("--max-slots", type=int, default=0,
                    help="engine slots (0 = one per request, traffic: 4)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="paged KV cache block size in tokens "
                         "(docs/SERVING.md); 0 = dense per-slot caches")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="physical KV pool capacity in blocks, incl. "
                         "scratch (docs/SERVING.md §Paged KV); 0 = auto "
                         "(slot floor + 2 slots of prefix-cache headroom)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable radix-tree prefix reuse (paged mode only)")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=0,
                    help="chunked-prefill scheduler token budget per round "
                         "(docs/SERVING.md §Scheduling); 0 = blocking "
                         "full-prompt admission")
    ap.add_argument("--kv-quant", default="none",
                    help="paged KV pool storage dtype (docs/SERVING.md "
                         "§KV quantization): none = model dtype; int8 = "
                         "quantized blocks against calibrated per-KV-head "
                         "scales (requires --calibrate and a paged "
                         "--kv-block-size)")
    ap.add_argument("--attn-impl", default="naive",
                    help="attention implementation (docs/SERVING.md "
                         "§Decode-attention memory model): naive = jnp "
                         "einsum; flash = Pallas kernels (gather-free "
                         "streaming decode over the paged pool, flash "
                         "prefill; interpret mode on CPU — correct but "
                         "slow off-TPU)")
    ap.add_argument("--max-queue", type=int, default=-1,
                    help="admission queue capacity (0 = no waiting room, "
                         "-1 = unbounded); overflow is rejected as "
                         "queue_full (open-loop replay only)")
    ap.add_argument("--queue-timeout", type=float, default=0.0,
                    help="reject requests waiting longer than this many "
                         "seconds (queue_timeout); 0 = wait forever "
                         "(open-loop replay only)")
    ap.add_argument("--max-concurrency", type=int, default=0,
                    help="most admitted requests in flight inside the "
                         "engine at once (open-loop replay only); 0 = the "
                         "engine's --max-slots")
    ap.add_argument("--no-degraded-mode", action="store_true",
                    help="disable the pool-pressure response ladder "
                         "(docs/SERVING.md §Fault tolerance); a stalled "
                         "admission round then wedges loudly instead of "
                         "flushing the prefix cache / shedding load")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request end-to-end deadline in seconds; "
                         "waiting requests expire, in-flight ones are "
                         "cancelled mid-decode (deadline_exceeded); 0 = no "
                         "deadline (open-loop replay only)")
    ap.add_argument("--max-retries", type=int, default=0,
                    help="retry attempts granted to requests ending in a "
                         "retryable fault class (docs/SERVING.md §Fault "
                         "tolerance); 0 = no retry (open-loop replay only)")
    ap.add_argument("--retry-backoff", type=float, default=0.5,
                    help="base retry backoff in seconds; attempt k waits "
                         "min(base * 2^(k-1), 8 * base) on the replay clock "
                         "(open-loop replay only)")
    ap.add_argument("--fault-every", type=int, default=0,
                    help="inject one deterministic fault every N supervisor "
                         "steps (docs/SERVING.md §Fault tolerance); 0 = no "
                         "injection (open-loop replay only)")
    ap.add_argument("--fault-kinds", default="step_error,nonfinite_logits",
                    help="comma list of fault kinds the injector cycles "
                         "through: step_error, nonfinite_logits, "
                         "pool_pressure, slow_step")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the injector's victim-slot choices "
                         "(deterministic given the seed)")


def validate_serve_flags(ap: argparse.ArgumentParser, args) -> None:
    """Validate the flag surface at the CLI, not deep inside the engine
    (the engine/frontend re-check their own invariants at construction)."""
    if args.kv_block_size < 0:
        ap.error(
            f"--kv-block-size: {args.kv_block_size} is negative; pass a "
            "positive block size (tokens per KV block, docs/SERVING.md) or "
            "0 for the dense per-slot layout"
        )
    if args.kv_pool_blocks < 0:
        ap.error(
            f"--kv-pool-blocks: {args.kv_pool_blocks} is negative; pass a "
            "pool capacity in blocks (docs/SERVING.md §Paged KV) or 0 for "
            "the automatic floor + prefix-cache headroom"
        )
    if args.kv_pool_blocks and args.kv_block_size == 0:
        ap.error(
            "--kv-pool-blocks only applies to the paged KV cache; it is "
            "meaningless with --kv-block-size 0 (dense layout has no pool)"
        )
    if args.no_prefix_cache and args.kv_block_size == 0:
        ap.error(
            "--no-prefix-cache only applies to the paged KV cache; it is "
            "meaningless with --kv-block-size 0 (dense layout has no "
            "prefix cache to disable)"
        )
    if args.no_degraded_mode and args.kv_block_size == 0:
        ap.error(
            "--no-degraded-mode only applies to the paged KV cache; the "
            "dense layout has no block pool, hence no pressure ladder to "
            "disable"
        )
    if args.prefill_chunk_tokens < 0:
        ap.error(
            f"--prefill-chunk-tokens: {args.prefill_chunk_tokens} is "
            "negative; pass a per-round token budget (docs/SERVING.md "
            "§Scheduling) or 0 for blocking full-prompt admission"
        )
    if args.attn_impl not in ModelOptions.ATTN_IMPLS:
        ap.error(
            f"--attn-impl: {args.attn_impl!r} unknown; valid: "
            f"{', '.join(ModelOptions.ATTN_IMPLS)} (flash routes decode "
            "through the gather-free paged-attention kernel where the "
            "plan keeps qk/pv exact)"
        )
    if args.kv_quant not in ModelOptions.KV_QUANTS:
        ap.error(
            f"--kv-quant: {args.kv_quant!r} unknown; valid: "
            f"{', '.join(ModelOptions.KV_QUANTS)} (int8 stores paged KV "
            "blocks quantized against calibrated per-KV-head scales, "
            "docs/SERVING.md §KV quantization)"
        )
    if args.kv_quant != "none" and args.kv_block_size == 0:
        ap.error(
            "--kv-quant int8 requires the paged KV layout; pass "
            "--kv-block-size > 0 (dense per-slot caches stay in model "
            "dtype)"
        )
    if args.kv_quant != "none" and not args.calibrate:
        ap.error(
            "--kv-quant int8 needs calibrated per-KV-head scales; add "
            "--calibrate so the PTQ pass bakes KV scales into the plan "
            "(docs/SERVING.md §KV quantization)"
        )
    # ---- open-loop replay flags (FrontendConfig + fault injection)
    if not args.traffic_trace:
        for flag, val, default in (
                ("--max-queue", args.max_queue, -1),
                ("--queue-timeout", args.queue_timeout, 0.0),
                ("--max-concurrency", args.max_concurrency, 0),
                ("--virtual-step", args.virtual_step, 0.0),
                ("--deadline", args.deadline, 0.0),
                ("--max-retries", args.max_retries, 0),
                ("--retry-backoff", args.retry_backoff, 0.5),
                ("--fault-every", args.fault_every, 0),
                ("--fault-kinds", args.fault_kinds,
                 "step_error,nonfinite_logits"),
                ("--fault-seed", args.fault_seed, 0)):
            if val != default:
                ap.error(f"{flag} only applies to open-loop replay; pass "
                         "--traffic-trace <file or spec> to select it")
        return
    from repro.serve.faults import FAULT_KINDS

    if args.deadline < 0:
        ap.error(f"--deadline: {args.deadline} is negative; pass an "
                 "end-to-end deadline in seconds > 0, or 0 to disable")
    if args.max_retries < 0:
        ap.error(f"--max-retries: {args.max_retries} is negative; pass the "
                 "retry attempts granted to retryable faults, or 0 to "
                 "disable retry")
    if args.retry_backoff < 0:
        ap.error(f"--retry-backoff: {args.retry_backoff} is negative; pass "
                 "a base backoff in seconds >= 0")
    if args.fault_every < 0:
        ap.error(f"--fault-every: {args.fault_every} is negative; pass an "
                 "injection period in supervisor steps, or 0 to disable")
    check_choices(ap, "--fault-kinds",
                  [k for k in args.fault_kinds.split(",") if k],
                  list(FAULT_KINDS))
    if args.max_queue < -1:
        ap.error(f"--max-queue: {args.max_queue} is invalid; pass a queue "
                 "capacity >= 0 (0 = no waiting room) or -1 for unbounded")
    if args.queue_timeout < 0:
        ap.error(f"--queue-timeout: {args.queue_timeout} is negative; pass "
                 "a timeout in seconds > 0, or 0 to disable")
    if args.max_concurrency < 0:
        ap.error(f"--max-concurrency: {args.max_concurrency} is negative; "
                 "pass an in-flight cap >= 1 (must not exceed --max-slots) "
                 "or 0 to inherit the engine's max_slots")
    if args.virtual_step < 0:
        ap.error(f"--virtual-step: {args.virtual_step} is negative; pass a "
                 "virtual round time in seconds > 0, or 0 for wall-clock "
                 "replay")
    if args.compare_exact:
        ap.error("--compare-exact is not supported with --traffic-trace "
                 "(the replay already checks streamed-vs-terminal parity)")
