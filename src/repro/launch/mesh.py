"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e-256,
("data", "model")).  Multi-pod: 2 pods x 256 = 512 chips with the leading
"pod" axis mapped onto the inter-pod (DCN) dimension — only pure-DP
collectives (gradient all-reduce) should cross it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
