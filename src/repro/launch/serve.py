"""Serving CLI over the continuous-batching engine (``repro.serve``).

Inference is the paper's target workload: this driver admits a batch of
requests (uniform or mixed prompt lengths) into the slotted serve engine,
decodes them through the fused ``lax.scan`` loop, and reports measured
tok/s plus the *modeled* ASTRA chip latency/energy per request
(``core.simulator`` — the numbers Figs. 5/6 are built from), under any of
the three ASTRA numeric modes:

  exact — bf16 reference            (accuracy oracle)
  int8  — ASTRA expectation path    (deployable quantized fast path)
  sc    — bit-true 128-bit streams  (the paper's stochastic arithmetic)

Execution modes are selected per GEMM site via ``--plan`` (preset name,
uniform mode, or JSON glob rules over the shared execution/simulator site
registry — docs/PLANS.md); ``--mode`` remains as the uniform shorthand.
``--calibrate`` runs a PTQ calibration pass (per-site activation scales)
on a synthetic batch before serving.

KV memory is paged by default (``--kv-block-size``, docs/SERVING.md):
attention KV lives in fixed-size pooled blocks with radix-tree prefix
reuse on pure global-attention stacks (``--no-prefix-cache`` disables the
reuse; ``--kv-block-size 0`` restores the dense per-slot layout).

``--prefill-chunk-tokens N`` turns on the chunked-prefill scheduler
(docs/SERVING.md §Scheduling): prompts are prefilled in bounded chunks
interleaved with decode chunks under a shared per-round token budget of
``N``, so admitting a long prompt never stalls in-flight decode for more
than one bounded dispatch (0 = blocking full-prompt admission).  Each
request reports measured queue wait / TTFT / inter-token latency next to
the modeled chip cost.

``--traffic-trace`` switches from one-shot batch serving to open-loop
trace replay through the admission-controlled front-end
(docs/SERVING.md §Traffic, SLOs, and backpressure): requests arrive on
the trace's schedule, ``--max-queue`` bounds the waiting line,
``--queue-timeout`` sheds stale waiters, and the run ends with the SLO
scorecard (p50/p95/p99 TTFT + ITL, rejection rate, goodput).  The trace
is either a JSON file written by ``repro.traffic`` or an inline spec
like ``chat:rate=4,n=32,seed=0`` (suites: chat, longdoc, agent, mixed).
``--virtual-step`` replays in deterministic virtual time instead of
wall time.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced \
      --batch 4 --prompt-len 32 --gen 16 --mode int8 --compare-exact
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced \
      --traffic-trace 'mixed:rate=8,n=32' --max-queue 16 --queue-timeout 2 \
      --max-slots 4 --virtual-step 0.05
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced \
      --prompt-mix 16,32,64 --batch 6 --gen 16 --temperature 0.8 --top-k 40
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced \
      --plan mixed --calibrate --batch 4 --gen 8
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced \
      --plan '{"*.qk|*.pv": "int8", "*_proj": "sc", "default": "exact"}'
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.astra_layer import MODES
from repro.core.energy import AstraChipConfig
from repro.core.plan import PRESET_PLANS, ExecutionPlan
from repro.launch.flags import add_serve_flags, validate_serve_flags
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import (
    GREEDY, SamplerConfig, ServeConfig, ServeEngine, make_fused_decode,
    packed_prefill,
)
from repro.serve.sampling import sample_next_token


def generate(model: Model, params, prompts: jax.Array, gen_len: int, max_len: int,
             sampler: SamplerConfig = GREEDY, key=None):
    """Uniform-length batch decode.  prompts [B, S0] (or [B, C, S0]).

    Kept as the simple entry point (packed prefill + one fused scan over
    all ``gen_len`` steps).  Returns (prompt+generated tokens, decode tok/s).
    """
    cfg = model.cfg
    prompts = jnp.asarray(prompts, jnp.int32)
    b = prompts.shape[0]
    s0 = prompts.shape[-1]
    if gen_len == 0:
        return prompts, 0.0
    if key is None:
        key = jax.random.PRNGKey(0)
    lengths = jnp.full((b,), s0, jnp.int32)
    last_logits, states = packed_prefill(
        model, params, prompts, lengths, max_len, lengths_static=[s0] * b
    )
    key, sub = jax.random.split(key)
    first = sample_next_token(last_logits, sampler, sub, cfg)  # [B,1] | [B,C,1]
    pieces = [prompts, first]
    tps = 0.0
    if gen_len > 1:
        fused = make_fused_decode(model)
        pos0 = jnp.full((b,), s0, jnp.int32)
        args = (params, first, states, pos0, key)
        kw = dict(steps=gen_len - 1, sampler=sampler)
        jax.block_until_ready(fused(*args, **kw))  # warm: compile outside t0
        t0 = time.time()
        toks, _, _ = fused(*args, **kw)
        jax.block_until_ready(toks)
        # count only the steps inside the timed window (the first token
        # came from prefill, before t0)
        tps = b * (gen_len - 1) / max(time.time() - t0, 1e-9)
        pieces.append(toks)
    return jnp.concatenate(pieces, axis=-1), tps


def _prompt_lengths(args) -> list:
    if args.prompt_mix:
        mix = [int(x) for x in args.prompt_mix.split(",")]
        return [mix[i % len(mix)] for i in range(args.batch)]
    return [args.prompt_len] * args.batch


def _make_prompts(cfg, lengths, key):
    prompts = []
    for i, l in enumerate(lengths):
        k = jax.random.fold_in(key, i)
        shape = (cfg.n_codebooks, l) if cfg.n_codebooks else (l,)
        prompts.append(np.asarray(jax.random.randint(k, shape, 0, cfg.vocab)))
    return prompts


def _run_engine(model, params, prompts, args, sampler):
    max_len = max(p.shape[-1] for p in prompts) + args.gen + 1
    cfg = ServeConfig(max_slots=args.max_slots or len(prompts), max_len=max_len,
                      chunk_steps=args.chunk_steps, sampler=sampler, seed=args.seed,
                      kv_block_size=args.kv_block_size,
                      kv_pool_blocks=args.kv_pool_blocks,
                      prefix_cache=not args.no_prefix_cache,
                      prefill_chunk_tokens=args.prefill_chunk_tokens,
                      attn_impl=args.attn_impl, kv_quant=args.kv_quant)
    # warm run on a throwaway engine: the jitted prefill/chunk programs are
    # memoized per model, so the timed run below measures serving, not XLA
    # compilation
    ServeEngine(model, params, cfg, chip=AstraChipConfig()).generate_batch(
        prompts, args.gen
    )
    engine = ServeEngine(model, params, cfg, chip=AstraChipConfig())
    t0 = time.time()
    outs = engine.generate_batch(prompts, args.gen)
    dt = max(time.time() - t0, 1e-9)
    return outs, sum(o.gen_len for o in outs) / dt, engine


def _parse_plan(ap: argparse.ArgumentParser, spec: str) -> ExecutionPlan:
    """Validate ``--plan`` at the CLI, not deep inside ComputeConfig."""
    try:
        return ExecutionPlan.from_spec(spec)
    except (ValueError, TypeError) as e:
        ap.error(
            f"--plan: {e}\n  presets: {', '.join(sorted(PRESET_PLANS))}\n"
            f"  uniform modes: {', '.join(MODES)}\n"
            "  or JSON rules, e.g. "
            '\'{"*.qk|*.pv": "int8", "*_proj": "sc", "default": "exact"}\''
        )


def _load_trace(ap: argparse.ArgumentParser, spec: str, cfg):
    """``--traffic-trace`` accepts a JSON trace file or an inline spec."""
    import os

    from repro.traffic import TrafficTrace, generate_trace, parse_trace_spec

    if os.path.exists(spec):
        return TrafficTrace.load(spec)
    try:
        kw = parse_trace_spec(spec)
    except ValueError as e:
        ap.error(f"--traffic-trace: {spec!r} is neither a file nor a valid "
                 f"spec: {e}")
    return generate_trace(vocab=cfg.vocab, n_codebooks=cfg.n_codebooks, **kw)


def _run_traffic(model, params, trace, args, sampler):
    """Open-loop replay: admission front-end + SLO scorecard."""
    from repro.serve import (
        EngineSupervisor, FrontendConfig, ServeFaultInjector, ServeFrontend,
    )
    from repro.traffic import SLOConfig, VirtualClock, evaluate, replay_trace, trace_max_len

    block = args.kv_block_size
    max_len = trace_max_len(trace)
    if block:
        max_len = -(-max_len // block) * block
    serve_cfg = ServeConfig(
        max_slots=args.max_slots or 4, max_len=max_len,
        chunk_steps=args.chunk_steps, sampler=sampler, seed=args.seed,
        kv_block_size=block, kv_pool_blocks=args.kv_pool_blocks,
        prefix_cache=not args.no_prefix_cache,
        prefill_chunk_tokens=args.prefill_chunk_tokens,
        attn_impl=args.attn_impl, kv_quant=args.kv_quant,
        degraded_mode=not args.no_degraded_mode)
    fe_cfg = FrontendConfig(
        max_queue_depth=None if args.max_queue < 0 else args.max_queue,
        queue_timeout_s=args.queue_timeout or None,
        max_concurrency=args.max_concurrency or None,
        default_deadline_s=args.deadline or None,
        max_retries=args.max_retries,
        retry_backoff_s=args.retry_backoff)
    virtual = args.virtual_step > 0

    def stack(force_virtual=False, inject=False):
        clk = VirtualClock() if (virtual or force_virtual) else None
        eng = ServeEngine(model, params, serve_cfg, chip=AstraChipConfig(),
                          clock=clk)
        sup = None
        if inject and args.fault_every > 0:
            # generous horizon: the schedule just needs to outlast the run
            inj = ServeFaultInjector.periodic(
                n_steps=100 * max(len(trace), 1) + args.fault_every,
                every=args.fault_every,
                kinds=[k for k in args.fault_kinds.split(",") if k],
                seed=args.fault_seed)
            sup = EngineSupervisor(eng, inj)
        elif args.fault_every > 0 or args.max_retries > 0 or args.deadline:
            sup = EngineSupervisor(eng)  # containment + audit, no injection
        return ServeFrontend(eng, fe_cfg, clock=clk, supervisor=sup)

    # warm pass on a throwaway stack in virtual time (no sleeps, no
    # faults): the jitted programs are memoized per model, so the replay
    # below measures serving, not XLA compilation
    replay_trace(stack(force_virtual=True), trace,
                 virtual_step_s=args.virtual_step or 0.05)
    frontend = stack(inject=True)
    result = replay_trace(frontend, trace,
                          virtual_step_s=args.virtual_step if virtual else None)
    slo = (SLOConfig(args.slo_ttft, args.slo_itl)
           if args.slo_ttft > 0 and args.slo_itl > 0 else None)
    m = evaluate(result.outputs, result.duration_s, slo,
                 offered_rps=trace.rate_rps)
    clock_kind = f"virtual step={args.virtual_step}s" if virtual else "wall"
    print(f"[traffic] {trace.suite} trace: {len(trace)} requests at "
          f"{trace.rate_rps:g} rps ({trace.arrival}), replayed in "
          f"{result.duration_s:.2f}s ({clock_kind})")
    print(f"  completed {m['n_completed']}/{m['n_offered']} "
          f"({m['completed_tok_s']:.1f} tok/s), rejected {m['n_rejected']} "
          f"{m['rejected_by_reason'] or ''}")
    st = result.stats
    print(f"  queue: p50 wait {m['queue_p50_s'] * 1e3:.1f} ms, high-water "
          f"depth {st['max_queue_depth']}"
          + (f" (cap {fe_cfg.max_queue_depth})"
             if fe_cfg.max_queue_depth is not None else ""))
    print(f"  TTFT p50/p95/p99: {m['ttft_p50_s'] * 1e3:.1f} / "
          f"{m['ttft_p95_s'] * 1e3:.1f} / {m['ttft_p99_s'] * 1e3:.1f} ms")
    print(f"  ITL  p50/p95/p99: {m['itl_p50_s'] * 1e3:.2f} / "
          f"{m['itl_p95_s'] * 1e3:.2f} / {m['itl_p99_s'] * 1e3:.2f} ms "
          f"(max {m['itl_max_s'] * 1e3:.2f} ms)")
    if slo is not None:
        print(f"  SLO (ttft<={slo.ttft_s}s, itl<={slo.itl_s}s): "
              f"{m['n_slo_met']}/{m['n_offered']} met "
              f"({m['slo_attainment']:.0%}), goodput {m['goodput_rps']:.2f} rps")
    if frontend.supervisor is not None:
        sup_st = frontend.supervisor.stats
        eng_st = frontend.engine.stats()
        print(f"  faults: {sup_st['faults_injected']} injected over "
              f"{sup_st['steps']} supervised steps, "
              f"{eng_st['n_quarantined']} quarantined / "
              f"{eng_st['n_cancelled']} cancelled / {eng_st['n_shed']} shed, "
              f"{st['retries']} retries, {sup_st['audits_run']} audits clean")
        if eng_st["degraded_transitions"]:
            path = " -> ".join(name for _, name in eng_st["degraded_transitions"])
            print(f"  degraded ladder: {path} (now {eng_st['degraded_level']})")
    return result.outputs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--prompt-mix", default="",
                    help="comma list of prompt lengths cycled over the batch, "
                         "e.g. 16,32,64 (continuous batching handles the mix)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--calibrate", action="store_true",
                    help="run a PTQ calibration pass (per-site activation "
                         "scales) on a synthetic batch before serving")
    ap.add_argument("--compare-exact", action="store_true",
                    help="also run exact mode and report token agreement")
    ap.add_argument("--traffic-trace", default="",
                    help="open-loop replay instead of one-shot batch: a "
                         "trace JSON written by repro.traffic, or an inline "
                         "spec like 'chat:rate=4,n=32,seed=0' "
                         "(docs/SERVING.md §Traffic)")
    add_serve_flags(ap)  # engine / plan / paged-KV / frontend surface
    ap.add_argument("--virtual-step", type=float, default=0.0,
                    help="replay on a virtual clock, each engine round "
                         "costing this many virtual seconds (deterministic "
                         "latencies); 0 = wall-clock replay")
    ap.add_argument("--slo-ttft", type=float, default=0.0,
                    help="TTFT bound in seconds for the goodput line "
                         "(0 with --slo-itl 0 = percentiles only)")
    ap.add_argument("--slo-itl", type=float, default=0.0,
                    help="max inter-token-gap bound in seconds for the "
                         "goodput line")
    args = ap.parse_args(argv)
    validate_serve_flags(ap, args)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    sampler = SamplerConfig(args.temperature, args.top_k)

    base_model = Model(cfg, ModelOptions())
    params = base_model.init(key)
    lengths = _prompt_lengths(args)
    prompts = _make_prompts(cfg, lengths, key)

    plan = _parse_plan(ap, args.plan) if args.plan else ExecutionPlan.from_spec(args.mode)
    plan_label = plan.name or args.plan or args.mode
    model = Model(cfg, ModelOptions(plan=plan))
    if args.calibrate:
        from repro.serve.prefill import pack_prompts

        cal_tokens, _ = pack_prompts(prompts, cfg)
        model = model.calibrate(params, {"tokens": cal_tokens})
        print(f"calibrated {len(model.plan.act_scales)} site activation scales"
              f" + {len(model.plan.kv_scales)} KV storage-site scales")
    if args.kv_quant != "none":
        # surface the engine's rejection reason at the flag that caused it
        # instead of a deep ValueError traceback (the engine re-raises the
        # same reason if constructed directly)
        from repro.serve.engine import kv_quant_reject_reason

        reason = kv_quant_reject_reason(model, args.kv_block_size)
        if reason is not None:
            ap.error(f"--kv-quant: {reason}")
    if args.traffic_trace:
        trace = _load_trace(ap, args.traffic_trace, cfg)
        return _run_traffic(model, params, trace, args, sampler)
    outs, tps, engine = _run_engine(model, params, prompts, args, sampler)
    print(f"[{plan_label}] {len(outs)} requests (prompt lens {sorted(set(lengths))}), "
          f"{args.gen} new tokens each: {tps:.1f} tok/s")
    kv = engine.kv_stats
    if kv:
        line = (f"  kv pool: {kv['pool_blocks']} blocks x "
                f"{kv['block_size']} tok, {kv['kv_quant']} storage "
                f"({kv['bytes_per_block']} B/block, "
                f"{kv['pool_bytes'] / 1e6:.2f} MB)")
        if not kv["prefix_cache"]:
            line += f"; prefix cache off: {kv['prefix_cache_off_reason']}"
        print(line)
    prefix_stats = engine.prefix_stats
    if prefix_stats:
        print(f"  prefix cache: {prefix_stats['hits']} hits / "
              f"{prefix_stats['misses']} misses, "
              f"{prefix_stats['hit_tokens']} prompt tokens reused, "
              f"{prefix_stats['evictions']} evictions")
    sched = engine.scheduler_stats
    if sched.get("active"):
        print(f"  scheduler: budget {sched['token_budget']} tok/round, "
              f"{sched['prefill_chunks']} prefill chunks / "
              f"{sched['prefill_tokens']} tokens over {sched['rounds']} rounds "
              f"({sched['starved_rounds']} decode-saturated)")
    timings = [o.timing for o in outs if o.timing is not None]
    if timings:
        print(f"  latency: queue {np.mean([t.queue_time_s for t in timings]) * 1e3:.1f} ms avg, "
              f"TTFT {np.mean([t.ttft_s for t in timings]) * 1e3:.1f} ms avg, "
              f"ITL {np.mean([t.mean_itl_s for t in timings]) * 1e3:.2f} ms avg / "
              f"{max(t.max_itl_s for t in timings) * 1e3:.2f} ms max")
    site_energy: dict = {}
    for o in outs:
        hw = o.hardware
        print(f"  req {o.request_id}: prompt {o.prompt.shape[-1]:>4} gen {o.gen_len:>3} | "
              f"ASTRA latency {hw.latency_s * 1e6:.3f} us, energy {hw.energy_j * 1e3:.3f} mJ, "
              f"{hw.energy_per_mac_j * 1e12:.3f} pJ/MAC")
        for site, e in hw.energy_by_site:
            site_energy[site] = site_energy.get(site, 0.0) + e
    top = sorted(site_energy.items(), key=lambda kv: -kv[1])[:5]
    total = sum(site_energy.values()) or 1.0
    print("  energy by site (top 5): " + ", ".join(
        f"{s} {e / total * 100:.1f}%" for s, e in top))

    # compare against exact iff the *effective* plan quantizes anything
    # (--plan overrides --mode, so the gate must look at the plan)
    from repro.core.plan import model_sites

    all_exact = all(model.plan.resolve(s).mode == "exact" for s in model_sites(cfg))
    if args.compare_exact and not all_exact:
        outs_ref, _, _eng = _run_engine(base_model, params, prompts, args, sampler)
        agree = np.mean([
            np.mean(o.tokens == r.tokens) for o, r in zip(outs, outs_ref)
        ])
        print(f"token agreement vs exact: {agree * 100:.2f}%")
    return outs


if __name__ == "__main__":
    main()
