"""Batched serving driver with the ASTRA execution modes.

Inference is the paper's target workload: this driver prefills a batch of
prompts, then decodes greedily with the KV/recurrent-state caches, under any
of the three ASTRA numeric modes:

  exact — bf16 reference            (accuracy oracle)
  int8  — ASTRA expectation path    (deployable quantized fast path)
  sc    — bit-true 128-bit streams  (the paper's stochastic arithmetic)

Alongside tokens/s it reports the *modeled* ASTRA chip latency/energy for
the same workload via ``core.simulator`` — the numbers Figs. 5/6 are built
from — so one command shows both numerical fidelity and the hardware story.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced \
      --batch 4 --prompt-len 32 --gen 16 --mode int8 --compare-exact
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.core.energy import AstraChipConfig
from repro.core.simulator import simulate
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.models.transformer import ModelOptions


def generate(model: Model, params, prompts: jax.Array, gen_len: int, max_len: int):
    """Greedy decode. prompts [B, S0] (or [B, C, S0]).  Returns tokens, t/s."""
    cfg = model.cfg
    b = prompts.shape[0]
    s0 = prompts.shape[-1]
    # feed the prompt through decode steps against a max_len-preallocated
    # state (robust across KV / ring-buffer / recurrent archs), then sample
    states = model.init_decode_state(b, max_len)
    decode = jax.jit(model.decode)
    logits = None
    for t in range(s0):
        tok_t = prompts[..., t : t + 1]
        logits, states = decode(params, tok_t, states, jnp.int32(t))
    out = [prompts]
    t0 = time.time()
    next_tok = jnp.argmax(logits[..., -1:, :], axis=-1).astype(jnp.int32)
    if cfg.n_codebooks:
        next_tok = jnp.swapaxes(next_tok, -1, -2)  # [B, C, 1]
    for t in range(s0, s0 + gen_len):
        out.append(next_tok)
        logits, states = decode(params, next_tok, states, jnp.int32(t))
        next_tok = jnp.argmax(logits[..., -1:, :], axis=-1).astype(jnp.int32)
        if cfg.n_codebooks:
            next_tok = jnp.swapaxes(next_tok, -1, -2)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=-1)
    return toks, (b * gen_len) / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mode", default="int8", choices=["exact", "int8", "sc"])
    ap.add_argument("--compare-exact", action="store_true",
                    help="also run exact mode and report token agreement")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    max_len = args.prompt_len + args.gen + 1

    base_model = Model(cfg, ModelOptions())
    params = base_model.init(key)
    shape = (args.batch, cfg.n_codebooks, args.prompt_len) if cfg.n_codebooks else (args.batch, args.prompt_len)
    prompts = jax.random.randint(key, shape, 0, cfg.vocab, jnp.int32)

    model = Model(cfg, ModelOptions(cc=ComputeConfig(args.mode)))
    toks, tps = generate(model, params, prompts, args.gen, max_len)
    print(f"[{args.mode}] generated {args.gen} tokens x batch {args.batch}: {tps:.1f} tok/s")

    if args.compare_exact and args.mode != "exact":
        toks_ref, _ = generate(base_model, params, prompts, args.gen, max_len)
        agree = float(jnp.mean((toks == toks_ref).astype(jnp.float32)))
        print(f"token agreement vs exact: {agree * 100:.2f}%")

    # hardware story: modeled ASTRA latency/energy for this workload
    chip = AstraChipConfig()
    rep = simulate(cfg, chip, seq=args.prompt_len + args.gen, batch=args.batch)
    print(f"ASTRA model: latency {rep.latency_s * 1e3:.3f} ms, "
          f"energy {rep.total_energy_j * 1e3:.3f} mJ, "
          f"{rep.macs / 1e9:.2f} GMACs ({rep.energy_per_mac_j * 1e12:.3f} pJ/MAC)")
    return toks


if __name__ == "__main__":
    main()
