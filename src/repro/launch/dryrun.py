import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 TPU v5e pods.  For each cell we

  1. build the step function (train_step for ``train`` shapes; prefill /
     decode serve steps otherwise),
  2. resolve in/out shardings from ``repro.parallel.sharding`` rules,
  3. ``jax.jit(...).lower(**input_specs).compile()``,
  4. record ``memory_analysis()`` (fits-per-device evidence),
     ``cost_analysis()`` (FLOPs / bytes for the roofline), and the
     collective schedule parsed from the optimized HLO,
  5. dump one JSON artifact per cell under --out (consumed by
     ``benchmarks/roofline.py`` and EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Tuple

import jax

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model, input_specs
from repro.models.transformer import ModelOptions
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.sharding import activation_mesh, batch_specs, param_specs, state_specs

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _link_traffic(op: str, result_bytes: int, g: int) -> float:
    """Per-device link bytes for ring algorithms of group size g.

    result_bytes is the per-device *result* shape from SPMD HLO:
    all-reduce result == full reduced tensor (2(g-1)/g rings);
    all-gather result == gathered tensor ((g-1)/g leaves each device);
    reduce-scatter result == scattered shard (operand = g x result).
    """
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-gather":
        return result_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return float(result_bytes) * (g - 1)
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute


def parse_collectives(hlo_text: str, scan_trip_count: int = 1) -> Dict[str, Any]:
    """Collective schedule from optimized SPMD HLO.

    Result-shape bytes per instruction; instructions whose metadata places
    them inside a scan body (op_name contains "/while/") execute
    ``scan_trip_count`` times and are weighted accordingly.
    """
    stats: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(\(?[a-z0-9\[\],{} ]*\)?)\s*\b(" + "|".join(COLLECTIVES) + r")(-start)?\(", line)
        if not m:
            continue
        op = m.group(2)
        result_part = m.group(1)
        shapes = _SHAPE_RE.findall(result_part)
        b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = _group_size(line)
        mult = scan_trip_count if "/while/" in line else 1
        s = stats.setdefault(op, {"count": 0, "bytes": 0, "traffic_bytes": 0.0})
        s["count"] += mult
        s["bytes"] += b * mult
        s["traffic_bytes"] += _link_traffic(op, b, g) * mult
    return stats


def _unwrap_cost(ca):
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca) if ca else {}


# --------------------------------------------------------------- HLO costs
# XLA's cost_analysis() counts while-loop bodies ONCE (trip counts are not
# folded in), which silently drops ~all FLOPs of a scan-over-layers model.
# We therefore re-count dots from the optimized HLO text, weighting each
# instruction by the trip counts of the loops it sits in (depth d =>
# prod(trips[:d]); scan metadata marks nesting as repeated "/while/" path
# segments).  Fusion subcomputations are skipped for byte accounting (their
# intermediates never hit HBM); dots are counted wherever they appear.
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_DOT_RE = re.compile(r"\bdot\(")
_DOT_ARGS_RE = re.compile(r"\bdot\(([^)]*)\)")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dims(s: str):
    return [int(d) for d in s.split(",")] if s else []


def _loop_mult(line: str, trips) -> int:
    depth = line.count("/while/")
    mult = 1
    for d in range(min(depth, len(trips))):
        mult *= max(trips[d], 1)
    return mult


def parse_hlo_costs(hlo_text: str, trips=(1,)) -> Dict[str, float]:
    """Trip-weighted FLOPs and HBM-byte proxy from optimized SPMD HLO.

    flops: 2 * prod(out_dims) * prod(lhs_contracting_dims) per dot,
    weighted by the trip counts of enclosing scans (depth d from repeated
    "/while/" metadata segments => prod(trips[:d])).
    bytes: dot operand+output bytes (traffic a perfectly-fused TPU program
    still moves through HBM/VMEM) + non-fusion instruction outputs (fusion
    subcomputation intermediates never materialize).
    """
    trips = tuple(int(t) for t in trips) or (1,)
    shapes: Dict[str, Tuple[str, str]] = {}
    flops = 0.0
    dot_bytes = 0.0
    out_bytes = 0.0
    in_fusion = False
    for line in hlo_text.splitlines():
        h = _HDR_RE.match(line.strip())
        if h:
            in_fusion = "fused" in h.group(1) or "wrapped" in h.group(1)
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, dtype, dims = m.group(1), m.group(2), m.group(3)
        shapes[name] = (dtype, dims)
        nbytes = _shape_bytes(dtype, dims)
        mult = _loop_mult(line, trips)
        if _DOT_RE.search(line):
            cm = _CDIMS_RE.search(line)
            args = _DOT_ARGS_RE.search(line)
            ops = _OPND_RE.findall(args.group(1)) if args else []
            if cm is not None and ops and ops[0] in shapes:
                lhs_dims = _dims(shapes[ops[0]][1])
                contract = 1
                for i in _dims(cm.group(1)):
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
                out_elems = 1
                for d in _dims(dims):
                    out_elems *= d
                flops += 2.0 * out_elems * contract * mult
                operand_bytes = sum(
                    _shape_bytes(*shapes[o]) for o in ops[:2] if o in shapes
                )
                dot_bytes += (operand_bytes + nbytes) * mult
        elif not in_fusion:
            out_bytes += nbytes * mult
    return {
        "hlo_flops": flops,
        "dot_bytes": dot_bytes,
        "other_bytes": out_bytes,
        "hlo_bytes": dot_bytes + out_bytes,
    }


def build_step(arch_name: str, shape_name: str, mesh, opts: ModelOptions,
               strategy: str = "tp_fsdp", kv_layout: str = "heads"):
    """Returns (jitted_fn, arg_specs) ready to .lower(*arg_specs)."""
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    model = Model(cfg, opts)
    specs = input_specs(cfg, shape)
    param_shapes = model.param_shapes()
    p_shard = param_specs(param_shapes, mesh, strategy)

    if shape.kind == "train":
        ocfg = AdamWConfig()
        opt_shapes = jax.eval_shape(adamw_init, param_shapes)
        o_shard = {
            "m": param_specs(opt_shapes["m"], mesh, strategy),
            "v": param_specs(opt_shapes["v"], mesh, strategy),
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        b_shard = batch_specs(specs, mesh, strategy)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                return model.loss(p, batch)[0]

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params2, opt2, stats = adamw_update(params, grads, opt_state, ocfg)
            return params2, opt2, {"loss": loss, **stats}

        fn = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        return fn, (param_shapes, opt_shapes, specs)

    if shape.kind == "prefill":
        b_shard = batch_specs(specs, mesh, strategy)

        def prefill_step(params, batch):
            return model.prefill(params, batch)

        state_shapes = jax.eval_shape(
            lambda p, b: model.prefill(p, b)[1], param_shapes, specs
        )
        s_shard = state_specs(state_shapes, mesh, shape.global_batch, kv_layout=kv_layout)
        fn = jax.jit(
            prefill_step,
            in_shardings=(p_shard, b_shard),
            out_shardings=(None, s_shard),
        )
        return fn, (param_shapes, specs)

    # decode: one token against a seq_len state
    s_shard = state_specs(specs["states"], mesh, shape.global_batch, kv_layout=kv_layout)
    tok_shard = batch_specs({"token": specs["token"]}, mesh)["token"]
    scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def serve_step(params, token, states, pos):
        return model.decode(params, token, states, pos)

    fn = jax.jit(
        serve_step,
        in_shardings=(p_shard, tok_shard, s_shard, scalar),
        out_shardings=(None, s_shard),
        donate_argnums=(2,),
    )
    return fn, (param_shapes, specs["token"], specs["states"], specs["pos"])


def resolve_auto(shape, cfg=None, model_axis: int = 16, n_devices: int = 256) -> Tuple[str, str]:
    """Per-shape optimized defaults, from the EXPERIMENTS.md SPerf hillclimbs:
    train -> pure ZeRO-3 (kills row-parallel activation all-reduces; experts
    keep EP; >=3.2x on every train cell); decode -> TP-only weights (no
    optimizer state to shard) + flash-decoding seq-sharded KV (up to 23x and
    the difference between fitting HBM or not).  Prefill and long-context
    keep the tp_fsdp baseline: measured, TP-only weights slightly regress
    small-model prefill (weight gathers there are cheap, activations
    dominate), and the recurrent-state long_500k cells have no KV cache for
    kv=seq to help."""
    if shape.kind == "train":
        # pure ZeRO-3 needs the batch to cover every device; otherwise the
        # leftover axis would just replicate work — keep TP there
        if shape.global_batch % n_devices == 0:
            return "fsdp", "heads"
        return "tp_fsdp", "heads"
    if shape.name.startswith("decode"):
        # flash-decoding seq-sharded KV only pays off when head-sharding
        # can't cover the axis (GQA kv-heads not divisible -> replication);
        # otherwise heads-sharding avoids the softmax partial all-reduces
        if cfg is not None and cfg.n_kv_heads % model_axis == 0:
            return "tp", "heads"
        return "tp", "seq"
    return "tp_fsdp", "heads"


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, opts: ModelOptions,
             strategy: str = "tp_fsdp", kv_layout: str = "heads") -> Dict[str, Any]:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "strategy": strategy, "kv_layout": kv_layout,
    }
    if strategy == "auto":
        strategy, kv_layout = resolve_auto(shape, cfg, n_devices=512 if multi_pod else 256)
        rec["strategy"], rec["kv_layout"] = strategy, kv_layout
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        t0 = time.time()
        fn, arg_specs = build_step(arch_name, shape_name, mesh, opts, strategy, kv_layout)
        with mesh, activation_mesh(mesh, strategy):
            lowered = fn.lower(*arg_specs)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        ma = compiled.memory_analysis()
        ca = _unwrap_cost(compiled.cost_analysis())
        hlo = compiled.as_text()
        coll = parse_collectives(hlo, scan_trip_count=max(cfg.n_pattern_units, 1))
        # trip-count nest: unit scan, then any per-time scan (sLSTM)
        hlo_costs = parse_hlo_costs(hlo, trips=(max(cfg.n_pattern_units, 1), shape.seq_len))
        rec.update(hlo_costs)
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            flops=ca.get("flops", 0.0),
            bytes_accessed=ca.get("bytes accessed", 0.0),
            memory={
                k: getattr(ma, k)
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes", "peak_memory_in_bytes",
                )
                if hasattr(ma, k)
            },
            collectives=coll,
            n_devices=int(jax.device_count()),
        )
    except Exception as e:  # a failure here is a bug in our sharding config
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--attn-impl", default="naive", choices=["naive", "flash"])
    ap.add_argument("--remat", default="true", choices=["true", "false"])
    ap.add_argument("--strategy", default="tp_fsdp", choices=["tp_fsdp", "fsdp", "ep_dp", "tp", "auto"])
    ap.add_argument("--kv", default="heads", choices=["heads", "seq"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    opts = ModelOptions(attn_impl=args.attn_impl, remat=args.remat == "true")
    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        tag = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
        if args.tag:
            tag += f"__{args.tag}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            rec = json.load(open(path))
            print(f"[cached] {tag}: {rec['status']}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        rec = run_cell(a, s, mp, opts, args.strategy, args.kv)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"  -> {rec['status']}"
              + (f" compile={rec.get('compile_s')}s flops={rec.get('flops'):.3e}" if rec["status"] == "ok" else
                 f" ({rec.get('reason', rec.get('error', ''))[:200]})"),
              flush=True)
        failures += rec["status"] == "error"
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
