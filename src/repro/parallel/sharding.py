"""Logical-axis sharding rules -> NamedSharding for every pytree we jit.

One rule table serves all ten architectures (DP / FSDP / TP / EP / SP):

* batch            -> ("pod", "data")     pure DP across pods (only gradient
                                          all-reduce crosses the DCN)
* GEMM input dim   -> "data"              FSDP / ZeRO-3 parameter+optimizer
                                          sharding; GSPMD inserts the
                                          all-gathers next to use sites
* GEMM output dim  / heads / vocab -> "model"   tensor parallelism
* MoE expert dim   -> "model"             expert parallelism (EP == TP axis;
                                          experts are small, one group per
                                          shard)
* KV-cache batch   -> ("pod", "data"), heads -> "model"
* recurrent state width -> "model"        SP-style state sharding for
                                          SSM/hybrid decode

Every assignment is guarded by divisibility: if a mesh axis does not divide
the dim (e.g. kv=8 heads on a 16-way model axis), the dim is replicated —
GSPMD keeps the program correct either way; the dry-run report shows the
consequence.  Rules are resolved per parameter *path*, so stacked scan
units (leading U dim) and multi-codebook tables (leading C dim) just get
leading ``None``s.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# trailing-dims logical rules per leaf name (regex on the flattened path).
# Convention: ("in", "out") GEMMs are (FSDP, TP).
_PARAM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"embedding/table$", ("model", "data")),          # (V, D): vocab TP + FSDP
    (r"head/w$", ("data", "model")),                   # (D, V)
    (r"(wq|wk|wv)/w$", ("data", "model")),
    (r"(wq|wk|wv)/b$", ("model",)),
    (r"wo/w$", ("model", "data")),
    (r"(up|gate)/w$", ("data", "model")),
    (r"down/w$", ("model", "data")),
    (r"(up|gate|down|wo)/b$", (None,)),
    (r"router/w$", ("data", None)),
    (r"mlp/(w_up|w_gate)$", ("model", "data", None)),  # (E, D, F): EP + FSDP
    (r"mlp/w_down$", ("model", None, "data")),         # (E, F, D)
    (r"w_in/w$", ("data", "model")),
    (r"w_out/w$", ("model", "data")),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"lam$", ("model",)),
    (r"(w_a|w_x)/w$", (None, "model")),
    (r"(w_a|w_x)/b$", ("model",)),
    (r"(w_up|w_gates|w_q|w_k|w_v|w_if)/w$", ("data", "model")),
    (r"(w_up|w_gates|w_q|w_k|w_v|w_if)/b$", ("model",)),
    (r"w_down/w$", ("model", "data")),
    (r"r_gates$", (None, None, None, None)),
    (r"(scale|bias)$", (None,)),
)


def logical_rules() -> Tuple[Tuple[str, Tuple], ...]:
    return _PARAM_RULES


# ------------------------------------------------------------- strategies
# Named parallelism strategies re-map the baseline (TP+FSDP) rule table:
#
# * "tp_fsdp" — baseline: GEMM input dim FSDP over "data", output dim TP
#   over "model" (megatron-style row/col parallel + ZeRO).
# * "fsdp"    — pure ZeRO-3: no tensor parallelism; every sharded param dim
#   spreads over the flattened ("data","model") axes and the batch does
#   too.  Kills the per-layer row-parallel activation all-reduces at the
#   cost of per-layer weight all-gathers — a large win when activations
#   outweigh weights (see EXPERIMENTS.md SPerf, qwen1.5-110b/train_4k).
# * "ep_dp"   — for MoE archs with small d_model: experts stay on "model"
#   (EP), everything else is DP/FSDP over "data" only (attention weights
#   are tiny; TP-ing them costs an all-reduce of the full activation per
#   layer).
STRATEGIES = ("tp_fsdp", "fsdp", "ep_dp", "tp")


def _remap_rule(rule: Tuple, strategy: str, is_expert: bool) -> Tuple:
    if strategy == "tp_fsdp":
        return rule
    if strategy == "fsdp" and is_expert:
        # experts keep their EP layout (E on "model", D FSDP on "data"):
        # token-side fsdp sharding + per-layer expert-weight gathers
        return rule
    out = []
    for entry in rule:
        if strategy == "fsdp":
            if entry == "data":
                out.append(("data", "model"))
            elif entry == "model":
                out.append(None)
            else:
                out.append(entry)
        elif strategy == "ep_dp":
            if is_expert:
                out.append(entry)  # experts keep EP over "model"
            elif entry == "model":
                out.append(None)
            else:
                out.append(entry)
        elif strategy == "tp":
            # inference: no optimizer state to shard -> drop FSDP, keep TP
            out.append(None if entry == "data" else entry)
    # at most one dim may use the combined axes; keep the first
    if strategy == "fsdp":
        seen = False
        for i, e in enumerate(out):
            if e == ("data", "model"):
                if seen:
                    out[i] = None
                seen = True
    return tuple(out)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return mesh.shape[entry]


def _guard(mesh: Mesh, spec: Tuple, shape: Tuple[int, ...]) -> P:
    """Drop axes that don't divide the dim; pad missing leading dims."""
    spec = tuple(spec)
    if len(spec) < len(shape):
        spec = (None,) * (len(shape) - len(spec)) + spec
    spec = spec[-len(shape):] if len(spec) > len(shape) else spec
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
        elif dim % _axis_size(mesh, entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def param_specs(shapes_tree, mesh: Mesh, strategy: str = "tp_fsdp"):
    """ShapeDtypeStruct tree -> NamedSharding tree via the rule table."""
    assert strategy in STRATEGIES, strategy

    def one(path, leaf):
        pstr = _path_str(path)
        for pat, rule in _PARAM_RULES:
            if re.search(pat, pstr):
                is_expert = bool(re.search(r"mlp/(w_up|w_gate|w_down)$", pstr))
                rule = _remap_rule(rule, strategy, is_expert)
                return NamedSharding(mesh, _guard(mesh, rule, leaf.shape))
        return NamedSharding(mesh, P())  # replicate by default

    return jax.tree_util.tree_map_with_path(one, shapes_tree)


def _dp_axes(mesh: Mesh, batch: int, strategy: str = "tp_fsdp"):
    """Largest batch-dividing contiguous run of the DP axes."""
    names = ("pod", "data", "model") if strategy == "fsdp" else ("pod", "data")
    cand = [a for a in names if a in mesh.shape]
    options = []
    for i in range(len(cand)):
        options.append(tuple(cand[i:]))  # drop outermost axes first
    options += [tuple(cand[:-1])] if len(cand) > 1 else []
    for axes in options:
        if axes and batch % _axis_size(mesh, axes) == 0:
            return axes
    return None


def batch_specs(specs_tree, mesh: Mesh, strategy: str = "tp_fsdp"):
    """Input batch tree: shard dim 0 (global batch) over the DP axes."""

    def one(path, leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        dp = _dp_axes(mesh, leaf.shape[0], strategy)
        spec = [None] * len(leaf.shape)
        if dp:
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, specs_tree)


# state field rules: (field, base_rank) -> trailing rule
def _state_rule(pstr: str, base_rank: int, kv_layout: str = "heads") -> Tuple:
    if re.search(r"\.(k|v)$", pstr) or pstr.endswith("/k") or pstr.endswith("/v"):
        if kv_layout == "seq":
            # flash-decoding layout: shard the sequence axis of the cache
            # over "model" — kv-head counts that don't divide the axis stop
            # mattering, the per-chip cache shrinks 16x, and the softmax
            # reduction over the sharded axis costs only tiny [B,H] partial
            # all-reduces (see EXPERIMENTS.md SPerf, qwen2.5-32b/decode_32k)
            return ("batch", None, "model", None)  # [B, kv, S, hd]
        return ("batch", "model", None, None)  # [B, kv, S, hd]
    if pstr.endswith("h") and base_rank == 2:
        return ("batch", "model")  # rglru h [B, R]
    if pstr.endswith("conv"):
        return ("batch", None, "model")
    if base_rank == 4:  # mlstm c [B, H, dk, dv]
        return ("batch", None, "model", None)
    if base_rank == 3:  # mlstm n / slstm fields [B, H, d]
        return ("batch", None, "model")
    if base_rank == 2:  # mlstm m [B, H]
        return ("batch", None)
    return ("batch",)


def state_specs(states_tree, mesh: Mesh, batch: int, in_units_rank_bump: bool = True,
                kv_layout: str = "heads"):
    """Decode-state tree -> shardings (KV caches, recurrent states)."""
    dp = _dp_axes(mesh, batch)

    def one(path, leaf):
        pstr = _path_str(path)
        rank = len(leaf.shape)
        base_rank = rank - 1 if "units" in pstr else rank
        rule = _state_rule(pstr, base_rank, kv_layout)
        # replace the symbolic "batch" with the dp axes
        rule = tuple(dp if r == "batch" else r for r in rule)
        return NamedSharding(mesh, _guard(mesh, rule, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, states_tree)


# ------------------------------------------------------- activation hints
# Explicit with_sharding_constraint hints at key activation sites keep GSPMD
# on the megatron-style layout (batch over DP axes, heads/ffn/experts over
# "model") instead of replicating activations inside the layer scan.
_ACT_RULES = {
    "tp_fsdp": {
        "batch": ("pod", "data"),
        "moe_batch": ("pod", "data"),
        "heads": "model",
        "ffn": "model",
        "experts": "model",
        "vocab": "model",
        "rnn": "model",
        "embed": None,
        "seq": None,
    },
    # pure ZeRO-3: batch spreads over every axis; no TP'd activation dims
    "fsdp": {
        "batch": ("pod", "data", "model"),
        "moe_batch": ("pod", "data"),
        "heads": None, "ffn": None, "experts": None, "vocab": None,
        "rnn": None, "embed": None, "seq": None,
    },
    # inference TP: same activation layout as tp_fsdp
    "tp": {
        "batch": ("pod", "data"),
        "moe_batch": ("pod", "data"),
        "heads": "model",
        "ffn": "model",
        "experts": "model",
        "vocab": "model",
        "rnn": "model",
        "embed": None,
        "seq": None,
    },
    # MoE EP without TP: only the expert axis uses "model"
    "ep_dp": {
        "batch": ("pod", "data"),
        "moe_batch": ("pod", "data"),
        "heads": None, "ffn": None, "experts": "model", "vocab": None,
        "rnn": None, "embed": None, "seq": None,
    },
}

_MESH_VAR: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_act_mesh", default=None
)
_STRAT_VAR: contextvars.ContextVar[str] = contextvars.ContextVar(
    "repro_act_strategy", default="tp_fsdp"
)


@contextlib.contextmanager
def activation_mesh(mesh: Optional[Mesh], strategy: str = "tp_fsdp"):
    """Enable activation sharding hints for model code built in this scope."""
    tok = _MESH_VAR.set(mesh)
    tok2 = _STRAT_VAR.set(strategy)
    try:
        yield
    finally:
        _MESH_VAR.reset(tok)
        _STRAT_VAR.reset(tok2)


def shard_act(x: jax.Array, logical: Tuple[Optional[str], ...]) -> jax.Array:
    """Apply a logical activation constraint (no-op outside activation_mesh)."""
    mesh = _MESH_VAR.get()
    if mesh is None:
        return x
    rules = _ACT_RULES[_STRAT_VAR.get()]
    spec = []
    for dim, name in zip(x.shape, logical):
        axes = rules.get(name) if name else None
        if axes is None:
            spec.append(None)
            continue
        if isinstance(axes, tuple):
            axes = tuple(a for a in axes if a in mesh.shape)
            # longest dividing suffix: e.g. batch 256 on ("pod","data","model")
            # = 512 falls back to ("data","model") = 256 instead of
            # replicating (a silent full-replication footgun on 3-axis meshes)
            while axes and dim % _axis_size(mesh, axes) != 0:
                axes = axes[1:]
            spec.append(axes if axes else None)
            continue
        elif axes not in mesh.shape:
            spec.append(None)
            continue
        spec.append(axes if dim % _axis_size(mesh, axes) == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def tree_shardings(tree, mesh: Mesh, kind: str, batch: Optional[int] = None):
    if kind == "params":
        return param_specs(tree, mesh)
    if kind == "batch":
        return batch_specs(tree, mesh)
    if kind == "state":
        assert batch is not None
        return state_specs(tree, mesh, batch)
    raise ValueError(kind)
