from repro.parallel.sharding import (
    batch_specs, param_specs, state_specs, logical_rules, tree_shardings,
)

__all__ = ["batch_specs", "param_specs", "state_specs", "logical_rules", "tree_shardings"]
