"""xlstm-125m — [ssm] 12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304.

sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].  d_ff=0: xLSTM blocks
carry their own up/down projections (mLSTM: 2x expansion; sLSTM: gated FFN
inside the block).  We use a 3:1 mLSTM:sLSTM repeating unit (12 layers = 3
units), following the paper's mLSTM-dominant LM recipes.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50_304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
)
