"""The five transformer models ASTRA evaluates (paper §III).

Transformer-base, BERT-base, ALBERT-base, ViT-base, OPT-350M.  These drive
the paper-reproduction benchmarks (accuracy, Figs 4-6); they are *additional*
to the ten assigned architectures.  Encoder models (BERT/ALBERT/ViT) are
run as bidirectional encoders by the simulator (no causal mask, no decode).
"""
from repro.configs.base import ArchConfig

# Vaswani et al. 2017, base: 6 enc + 6 dec; ASTRA maps the matmul workload,
# we model it as 12 layers of d=512.
TRANSFORMER_BASE = ArchConfig(
    name="transformer-base", family="dense", n_layers=12, d_model=512,
    n_heads=8, n_kv_heads=8, head_dim=64, d_ff=2048, vocab=37_000,
    norm="layernorm", act="gelu", source="Vaswani et al. 2017",
)

BERT_BASE = ArchConfig(
    name="bert-base", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab=30_522,
    norm="layernorm", act="gelu", source="Devlin et al. 2019",
)

# ALBERT shares one layer's params across 12 steps; compute equals BERT-base,
# parameters ~12x smaller — the simulator distinguishes weight *reads* from
# unique weights via `weight_sharing_factor`.
ALBERT_BASE = ArchConfig(
    name="albert-base", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab=30_000,
    norm="layernorm", act="gelu", source="Lan et al. 2020",
)

# ViT-base/16: 224x224 -> 196 patches + cls.
VIT_BASE = ArchConfig(
    name="vit-base", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab=1_000,
    norm="layernorm", act="gelu", source="Dosovitskiy et al. 2021",
)

OPT_350M = ArchConfig(
    name="opt-350m", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab=50_272,
    norm="layernorm", act="gelu", source="Zhang et al. 2022",
)

PAPER_MODELS = {
    m.name: m for m in (TRANSFORMER_BASE, BERT_BASE, ALBERT_BASE, VIT_BASE, OPT_350M)
}

# Workload sequence lengths used by the paper's inference evaluation
# (typical published settings for each model family).
PAPER_SEQ_LEN = {
    "transformer-base": 128,
    "bert-base": 128,
    "albert-base": 128,
    "vit-base": 197,
    "opt-350m": 512,
}
