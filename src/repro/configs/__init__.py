"""Config registry: ``get_arch(id)`` / ``ARCHS`` / shapes."""
from repro.configs.base import ArchConfig, MoEConfig, ShapeConfig, SHAPES, shape_applicable
from repro.configs.stablelm_1_6b import CONFIG as _stablelm
from repro.configs.qwen1_5_110b import CONFIG as _qwen110b
from repro.configs.qwen1_5_0_5b import CONFIG as _qwen05b
from repro.configs.qwen2_5_32b import CONFIG as _qwen32b
from repro.configs.recurrentgemma_2b import CONFIG as _rg2b
from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.llama3_2_vision_90b import CONFIG as _llamav
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite
from repro.configs.paper_models import PAPER_MODELS, PAPER_SEQ_LEN

ARCHS = {
    c.name: c
    for c in (
        _stablelm, _qwen110b, _qwen05b, _qwen32b, _rg2b,
        _xlstm, _musicgen, _llamav, _qwen3moe, _granite,
    )
}

ALL_MODELS = dict(ARCHS)
ALL_MODELS.update(PAPER_MODELS)


def get_arch(name: str) -> ArchConfig:
    if name not in ALL_MODELS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ALL_MODELS)}")
    return ALL_MODELS[name]


__all__ = [
    "ArchConfig", "MoEConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "ARCHS", "ALL_MODELS", "PAPER_MODELS", "PAPER_SEQ_LEN", "get_arch",
]
