"""stablelm-1.6b — [dense] 24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.

[hf:stabilityai/stablelm-2-1_6b; unverified]. StableLM-2 details: partial
rotary (25%), LayerNorm, SiLU-gated MLP, no QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab=100_352,
    qkv_bias=False,
    rope_pct=0.25,
    norm="layernorm",
    act="swiglu",
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
