"""recurrentgemma-2b — [hybrid] 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.

RG-LRU + local attention, 1 attn : 2 recurrent [arXiv:2402.19427; hf].
Griffin pattern (rec, rec, local-MQA); 26 layers = 8 full units + 2 recurrent
remainder.  head_dim=256 (MQA), GeGLU MLP, sliding window 2048,
attention-logit softcap per Griffin.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256_000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    d_rnn=2560,
    conv_width=4,
    norm="rmsnorm",
    act="geglu",
    logit_softcap=0.0,
    tie_embeddings=True,
    source="arXiv:2402.19427; hf",
)
