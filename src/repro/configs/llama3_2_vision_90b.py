"""llama-3.2-vision-90b — [vlm] 100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

Cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; unverified].
100 decoder layers with a cross-attention layer every 5th (20 xattn layers),
matching the 90B layout.  The vision tower is a STUB per the assignment —
``input_specs()`` provides precomputed patch embeddings
(B, vision_tokens, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab=128_256,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    vision_tokens=6_404,  # 4 tiles x 1601 patches
    norm="rmsnorm",
    act="swiglu",
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
