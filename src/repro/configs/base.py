"""Architecture configuration system.

Every selectable architecture (``--arch <id>``) is described by an
:class:`ArchConfig`.  The model builder in ``repro.models.model`` consumes
these declaratively — adding an architecture means adding a config file, not
new model code.

Block kinds (``block_pattern`` is the repeating unit; layers are
``pattern * (n_layers // len(pattern)) + pattern[:remainder]``):

* ``attn``   — global causal self-attention (GQA) + MLP/MoE
* ``local``  — sliding-window causal self-attention + MLP
* ``xattn``  — cross-attention to frontend embeddings + MLP (VLM)
* ``rglru``  — Griffin/RecurrentGemma recurrent block (conv1d + RG-LRU) + MLP
* ``mlstm``  — xLSTM mLSTM block (matrix memory, parallelizable)
* ``slstm``  — xLSTM sLSTM block (scalar memory, sequential scan)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

VALID_BLOCKS = ("attn", "local", "xattn", "rglru", "mlstm", "slstm")
VALID_FAMILIES = ("dense", "hybrid", "ssm", "audio", "vlm", "moe")


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN replacing the dense MLP in every block."""

    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | hybrid | ssm | audio | vlm | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    block_pattern: Tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    # attention details
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0  # stablelm-2 uses partial rotary (25%)
    window: int = 0  # sliding-window size for "local" blocks
    logit_softcap: float = 0.0
    # norms / activations
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "swiglu"  # swiglu | geglu | gelu
    tie_embeddings: bool = False
    # modality frontends (stubs per assignment: precomputed embeddings)
    n_codebooks: int = 0  # audio: EnCodec token grid (B, n_codebooks, S)
    vision_tokens: int = 0  # vlm: precomputed patch embeds (B, vision_tokens, d_model)
    # recurrent widths
    d_rnn: int = 0  # RG-LRU width (0 -> d_model)
    conv_width: int = 4  # Griffin temporal conv width
    # numerics
    dtype: str = "bfloat16"
    # bookkeeping
    source: str = ""  # provenance tag from the assignment table

    def __post_init__(self):
        assert self.family in VALID_FAMILIES, self.family
        for b in self.block_pattern:
            assert b in VALID_BLOCKS, b
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.d_rnn == 0:
            object.__setattr__(self, "d_rnn", self.d_model)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA group size"

    # ------------------------------------------------------------------
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, pattern repeated/truncated to n_layers."""
        p = self.block_pattern
        reps = (self.n_layers + len(p) - 1) // len(p)
        return tuple((p * reps)[: self.n_layers])

    @property
    def n_pattern_units(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def n_remainder_layers(self) -> int:
        return self.n_layers - self.n_pattern_units * len(self.block_pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_subquadratic(self) -> bool:
        """True if decode state is bounded (no full-seq KV cache)."""
        return all(k in ("rglru", "mlstm", "slstm", "local") for k in self.layer_kinds)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += d * v * max(1, self.n_codebooks or 1)  # lm head(s)
        if self.n_codebooks:
            n += (self.n_codebooks - 1) * v * d  # extra codebook embeddings
        for kind in self.layer_kinds:
            n += self._block_params(kind)
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        per_expert = 3 * self.d_model * m.d_expert
        dead = (m.n_experts - m.top_k) * per_expert * self.n_layers
        return self.param_count() - dead

    def _block_params(self, kind: str) -> int:
        d = self.d_model
        n = 2 * d  # two norms
        if kind in ("attn", "local", "xattn"):
            n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                n += self.q_dim + 2 * self.kv_dim
        elif kind == "rglru":
            r = self.d_rnn
            n += d * 2 * r + r * self.conv_width + 2 * r + r * d  # proj,conv,lru,out
        elif kind == "mlstm":
            # up-proj (2x expand), q/k/v projs in expanded space, gates, down
            e = 2 * d
            n += d * 2 * e + 3 * e * e // 4 + 2 * e + e * d
        elif kind == "slstm":
            h = d
            n += 4 * d * h + 4 * h * h // max(self.n_heads, 1) + 4 * h + 2 * d * h
        if kind in ("attn", "local", "xattn"):
            if self.moe is not None:
                m = self.moe
                n += d * m.n_experts  # router
                n += m.n_experts * 3 * d * m.d_expert
            elif self.d_ff > 0:
                mult = 3 if self.act in ("swiglu", "geglu") else 2
                n += mult * d * self.d_ff
        elif kind in ("rglru",) and self.d_ff > 0:
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            n += mult * d * self.d_ff
        return n

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=max(len(self.block_pattern), 2 if self.n_remainder_layers else len(self.block_pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            window=min(self.window, 32) if self.window else 0,
            d_rnn=64,
            vision_tokens=16 if self.vision_tokens else 0,
            moe=None
            if self.moe is None
            else dataclasses.replace(self.moe, n_experts=4, top_k=2, d_expert=32),
            name=self.name + "-smoke",
        )
        # keep enough layers to exercise the full pattern incl. remainder
        if self.n_remainder_layers:
            small["n_layers"] = len(self.block_pattern) + self.n_remainder_layers
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell is defined, and why not if skipped."""
    if shape.name == "long_500k" and not arch.is_subquadratic:
        return False, "long_500k needs sub-quadratic attention; %s is full-attention" % arch.name
    return True, ""
