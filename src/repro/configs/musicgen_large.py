"""musicgen-large — [audio] 48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.

Decoder-only over EnCodec tokens [arXiv:2306.05284; hf].  4 RVQ codebooks:
input embedding is the sum of 4 codebook embeddings; output is 4 parallel
LM heads (delay interleaving handled by the data pipeline).  The EnCodec
modality frontend is a STUB per the assignment — ``input_specs()`` provides
the precomputed token grid (B, 4, S).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    n_codebooks=4,
    norm="layernorm",
    act="gelu",
    source="arXiv:2306.05284; hf",
)
