"""CLI: ``python -m repro.analysis [paths...] [--strict] [--json] ...``

Exit codes: 0 — clean; 1 — findings; 2 — usage/internal error.  CI runs
``python -m repro.analysis src/ --strict --json-out artifacts/lint.json``
(see .github/workflows/ci.yml §lint).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.analysis import CHECKERS, run_analysis


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-specific AST invariant linter (docs/ANALYSIS.md).",
    )
    ap.add_argument("paths", nargs="*", default=["src/"],
                    help="files or directories to lint (default: src/)")
    ap.add_argument("--strict", action="store_true",
                    help="also police suppressions: justifications required, "
                         "unknown check names are findings")
    ap.add_argument("--disable", action="append", default=[], metavar="CHECK",
                    help="skip a checker (repeatable, or comma-separated)")
    ap.add_argument("--json", action="store_true",
                    help="print findings + stats as JSON instead of text")
    ap.add_argument("--json-out", metavar="PATH",
                    help="also write the JSON report to PATH")
    ap.add_argument("--root", help="repo root (default: walk up from the "
                                   "first path to pyproject.toml)")
    ap.add_argument("--list-checks", action="store_true",
                    help="list registered checkers and exit")
    args = ap.parse_args(argv)

    if args.list_checks:
        width = max(len(n) for n in CHECKERS)
        for name in sorted(CHECKERS):
            c = CHECKERS[name]
            kind = "repo " if c.repo_level else "file "
            print(f"{name:<{width}}  [{kind}]  {c.doc}")
        return 0

    disable = [d for spec in args.disable for d in spec.split(",") if d]
    unknown = sorted(set(disable) - set(CHECKERS))
    if unknown:
        print(f"error: --disable names unknown checker(s): {unknown}; "
              f"known: {sorted(CHECKERS)}", file=sys.stderr)
        return 2
    paths = args.paths or ["src/"]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"error: no such path(s): {missing}", file=sys.stderr)
        return 2

    findings, stats = run_analysis(paths, root=args.root, disable=disable,
                                   strict=args.strict)
    report = {"findings": [f.as_dict() for f in findings], "stats": stats}
    if args.json_out:
        out_dir = os.path.dirname(os.path.abspath(args.json_out))
        os.makedirs(out_dir, exist_ok=True)
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
    if args.json:
        json.dump(report, sys.stdout, indent=2)
        print()
    else:
        for f in findings:
            print(f.render())
        counts = ", ".join(f"{k}={v}" for k, v in sorted(stats["counts"].items()))
        mode = " [strict]" if args.strict else ""
        print(f"repro-lint{mode}: {len(findings)} finding(s) in "
              f"{stats['n_files']} file(s), {len(stats['checkers'])} "
              f"checker(s) active" + (f" ({counts})" if counts else ""))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
