"""kernel-contract: every kernels/* subpackage keeps the kernel/ops/ref trio.

The six kernel subpackages share one shape (DESIGN.md §Kernels):
``kernel.py`` holds the Pallas body, ``ops.py`` the public jit wrappers,
``ref.py`` the jnp oracle the tests compare against, and ``__init__.py``
re-exports the ops surface.  The contract is what makes "validated on CPU
with interpret=True against ref.py" a property of the *tree*, not of
whichever kernels someone remembered to test:

* all four files exist;
* ``ops.py`` exposes >= 1 public function, ``ref.py`` >= 1 public
  ``*_ref`` function;
* ``__init__.py`` re-exports only names ``ops.py`` actually defines;
* for same-stem pairs (``foo`` in ops, ``foo_ref`` in ref) the oracle's
  required parameters are a subset of the op's (the op may add tuning
  kwargs like ``bm``/``interpret``, never drop semantic ones);
* some module under ``tests/`` imports the subpackage AND one of its
  ``*_ref`` oracles — a reference-parity test exists.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, List, Set, Tuple

from repro.analysis.core import Finding, RepoContext, checker

KERNELS_REL = "src/repro/kernels"
TRIO = ("kernel.py", "ops.py", "ref.py", "__init__.py")


def _public_defs(tree: ast.AST) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, ast.FunctionDef) and not n.name.startswith("_")}


def _params(fn: ast.FunctionDef) -> Tuple[Set[str], Set[str]]:
    """(all parameter names, required parameter names)."""
    a = fn.args
    pos = [p.arg for p in a.posonlyargs + a.args]
    kw = [p.arg for p in a.kwonlyargs]
    names = set(pos) | set(kw)
    n_required_pos = len(pos) - len(a.defaults)
    required = set(pos[:n_required_pos])
    required |= {p.arg for p, d in zip(a.kwonlyargs, a.kw_defaults) if d is None}
    return names, required


def _subpackages(ctx: RepoContext) -> List[str]:
    base = os.path.join(ctx.root, KERNELS_REL)
    if not os.path.isdir(base):
        return []
    return sorted(
        d for d in os.listdir(base)
        if os.path.isdir(os.path.join(base, d)) and not d.startswith("__")
    )


def _tests_text(ctx: RepoContext) -> str:
    tdir = os.path.join(ctx.root, "tests")
    if not os.path.isdir(tdir):
        return ""
    chunks = []
    for name in sorted(os.listdir(tdir)):
        if name.endswith(".py"):
            chunks.append(ctx.read(f"tests/{name}") or "")
    return "\n".join(chunks)


@checker("kernel-contract", scope=("src/repro/kernels/*",), repo_level=True)
def check(ctx: RepoContext) -> Iterator[Finding]:
    """Cross-check every kernels/* subpackage against the trio contract."""
    tests = _tests_text(ctx)
    for pkg in _subpackages(ctx):
        rel = f"{KERNELS_REL}/{pkg}"
        missing = [f for f in TRIO
                   if not os.path.exists(os.path.join(ctx.root, rel, f))]
        if missing:
            yield Finding(
                "kernel-contract", f"{rel}/__init__.py", 1,
                f"kernel subpackage {pkg!r} is missing {missing}; every "
                "kernel ships the kernel/ops/ref trio (DESIGN.md §Kernels)")
            continue
        ops_tree = ctx.parse(f"{rel}/ops.py")
        ref_tree = ctx.parse(f"{rel}/ref.py")
        init_tree = ctx.parse(f"{rel}/__init__.py")
        if ops_tree is None or ref_tree is None or init_tree is None:
            continue  # unreadable/unparseable files surface as 'parse'
        ops = _public_defs(ops_tree)
        refs = _public_defs(ref_tree)
        if not ops:
            yield Finding("kernel-contract", f"{rel}/ops.py", 1,
                          f"{pkg}/ops.py defines no public wrapper function")
        ref_named = {n for n in refs if n.endswith("_ref")}
        if not ref_named:
            yield Finding(
                "kernel-contract", f"{rel}/ref.py", 1,
                f"{pkg}/ref.py defines no public '*_ref' oracle; the parity "
                "tests need a jnp reference to compare the kernel against")
        # __init__ re-exports resolve to real ops definitions
        for node in init_tree.body:
            if (isinstance(node, ast.ImportFrom) and node.module
                    and node.module.endswith(f"{pkg}.ops")):
                for a in node.names:
                    if a.name != "*" and a.name not in ops:
                        yield Finding(
                            "kernel-contract", f"{rel}/__init__.py",
                            node.lineno,
                            f"__init__ re-exports {a.name!r} which "
                            f"{pkg}/ops.py does not define")
        # same-stem signature containment: foo_ref's required params <= foo's
        for name, fn in ops.items():
            ref_fn = refs.get(f"{name}_ref")
            if ref_fn is None:
                continue
            op_names, _ = _params(fn)
            _, ref_required = _params(ref_fn)
            extra = ref_required - op_names
            if extra:
                yield Finding(
                    "kernel-contract", f"{rel}/ops.py", fn.lineno,
                    f"{name} is missing parameter(s) {sorted(extra)} that "
                    f"its oracle {name}_ref requires; the public signatures "
                    "must stay compatible for the parity tests")
        # a reference-parity test exists
        if f"repro.kernels.{pkg}" not in tests:
            yield Finding(
                "kernel-contract", f"{rel}/__init__.py", 1,
                f"no module under tests/ imports repro.kernels.{pkg}; add a "
                "reference-parity test (see tests/test_kernels.py)")
        elif ref_named and not any(r in tests for r in sorted(ref_named)):
            yield Finding(
                "kernel-contract", f"{rel}/ref.py", 1,
                f"tests import repro.kernels.{pkg} but never one of its "
                f"oracles {sorted(ref_named)}; kernel output must be "
                "compared against the reference, not just executed")
