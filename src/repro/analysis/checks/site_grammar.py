"""site-grammar: site-name literals follow the ``core/plan.py`` grammar.

Execution sites are strings shared across three subsystems (the model's
plan routing, the simulator op graph, PTQ calibration):
``L{li}.{kind}.{op}`` for GEMMs, ``lm_head``, and ``L{li}.kv.{k,v}`` for
KV storage (docs/PLANS.md §Site naming grammar).  A typo'd literal —
``"L0.attn.qq"``, a glob rule matching nothing — fails silently: globs
that match no site simply never fire.  This checker cross-checks every
site-shaped string literal in ``src/repro`` against the vocabulary it
extracts from ``core/plan.py`` itself (``_BLOCK_GEMMS``/``_ATTN_OPS``
plus the MLP/MoE extras of ``block_site_ops`` — the same tables
``model_sites``/``kv_sites`` generate from), so the checker and the
registry cannot drift apart.

A literal is treated as site-shaped when it is ``lm_head``, starts with a
concrete ``L<digit>.`` layer prefix, or is a glob whose words overlap the
site vocabulary (``"*.qk|*.pv"``, ``"*_proj"``); ordinary globs like
``"*.json"`` are ignored.  Each ``|``-alternative must then match at
least one generatable site.
"""
from __future__ import annotations

import ast
import fnmatch
import re
from typing import Dict, Iterator, List, Set, Tuple

from repro.analysis.core import Finding, RepoContext, SourceFile, checker

PLAN_REL = "src/repro/core/plan.py"
MAX_LAYERS = 128  # universe depth: larger than any zoo config

# fallback vocabulary (used when core/plan.py is absent, e.g. in fixture
# repos) — mirrors plan.py's tables at the time of writing
_DEFAULT_GEMMS: Dict[str, Tuple[str, ...]] = {
    "attn": ("q_proj", "kv_proj", "qk", "pv", "o_proj"),
    "local": ("q_proj", "kv_proj", "qk", "pv", "o_proj"),
    "xattn": ("q_proj", "kv_proj", "qk", "pv", "o_proj"),
    "rglru": ("in_proj", "gates", "out_proj"),
    "mlstm": ("up_proj", "qkv", "gates", "down_proj"),
    "slstm": ("gates_in", "up", "down"),
}
_DEFAULT_EXTRAS = ("router", "expert_up", "expert_down", "up", "down")

_GLOB_CHARS = set("*?[")
_ALT_RE = re.compile(r"^[A-Za-z0-9_.*?\[\]\-]+$")
_WORD_RE = re.compile(r"[a-z0-9_]+")


def _extract_vocab(ctx: RepoContext) -> Tuple[Dict[str, Tuple[str, ...]], Tuple[str, ...]]:
    """(kind -> GEMM ops, extra MLP/MoE ops) from core/plan.py's AST."""
    tree = ctx.parse(PLAN_REL)
    if tree is None:
        return _DEFAULT_GEMMS, _DEFAULT_EXTRAS
    consts: Dict[str, Tuple[str, ...]] = {}
    gemms: Dict[str, Tuple[str, ...]] = {}
    extras: List[str] = []
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name, val = node.targets[0].id, node.value
            if isinstance(val, ast.Tuple) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in val.elts
            ):
                consts[name] = tuple(e.value for e in val.elts)
            elif isinstance(val, ast.Dict) and name == "_BLOCK_GEMMS":
                for k, v in zip(val.keys, val.values):
                    if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                        continue
                    if isinstance(v, ast.Name):
                        gemms[k.value] = consts.get(v.id, ())
                    elif isinstance(v, ast.Tuple):
                        gemms[k.value] = tuple(
                            e.value for e in v.elts
                            if isinstance(e, ast.Constant) and isinstance(e.value, str)
                        )
        elif isinstance(node, ast.FunctionDef) and node.name == "block_site_ops":
            for sub in ast.walk(node):
                if isinstance(sub, ast.List):
                    extras += [e.value for e in sub.elts
                               if isinstance(e, ast.Constant)
                               and isinstance(e.value, str)]
    if not gemms:
        return _DEFAULT_GEMMS, _DEFAULT_EXTRAS
    return gemms, tuple(extras) or _DEFAULT_EXTRAS


def _universe(ctx: RepoContext) -> Tuple[Set[str], Set[str]]:
    """(every generatable site name, vocabulary hint words).  Cached on
    the context — building it walks plan.py once per run."""
    cached = getattr(ctx, "_site_universe", None)
    if cached is not None:
        return cached
    gemms, extras = _extract_vocab(ctx)
    sites: Set[str] = {"lm_head"}
    for li in range(MAX_LAYERS):
        for kind, ops in gemms.items():
            for op in tuple(ops) + tuple(extras):
                sites.add(f"L{li}.{kind}.{op}")
        sites.add(f"L{li}.kv.k")
        sites.add(f"L{li}.kv.v")
    hints: Set[str] = {"kv", "lm_head"} | set(gemms) | set(extras)
    for ops in gemms.values():
        hints.update(ops)
        hints.update(op.rsplit("_", 1)[-1] for op in ops)  # "proj" etc.
    ctx._site_universe = (sites, hints)
    return sites, hints


def _site_shaped(alt: str, hints: Set[str]) -> bool:
    if alt == "lm_head" or re.match(r"^L\d+\.", alt):
        return True
    if not (_GLOB_CHARS & set(alt)) or not _ALT_RE.match(alt):
        return False
    words = _WORD_RE.findall(alt.lower())
    return any(w in hints for w in words)


@checker("site-grammar", scope=("src/repro/*",))
def check(sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
    """Validate site-shaped string literals against the plan grammar."""
    if sf.rel.startswith("src/repro/analysis/"):
        return  # the linter's own vocabulary tables are not site usage
    sites, hints = _universe(ctx)
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Constant) and isinstance(node.value, str)):
            continue
        s = node.value
        if not s or len(s) > 120 or any(c.isspace() for c in s):
            continue
        alts = s.split("|")
        if not all(alts):
            continue
        if not any(_site_shaped(a, hints) for a in alts):
            continue
        for alt in alts:
            if alt == "default":
                continue  # from_spec's fallback key rides along in rule dicts
            ok = (alt in sites if not (_GLOB_CHARS & set(alt))
                  else any(fnmatch.fnmatchcase(site, alt) for site in sites))
            if not ok:
                yield Finding(
                    "site-grammar", sf.rel, node.lineno,
                    f"site pattern {alt!r} matches no site the "
                    "L{li}.{kind}.{op} / lm_head / L{li}.kv.{k,v} grammar "
                    "can generate (vocabulary from core/plan.py); a rule "
                    "that matches nothing never fires — fix the typo or "
                    "drop the rule (docs/PLANS.md §Site naming grammar)")
