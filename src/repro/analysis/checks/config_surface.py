"""config-surface: every serve config field stays reachable and documented.

``ServeConfig`` / ``FrontendConfig`` / ``ModelOptions`` are the serving
stack's whole tuning surface.  Fields rot in two directions: a field is
added but never exposed as a CLI flag (unreachable from
``launch/serve.py`` — ``kv_pool_blocks`` and ``max_concurrency`` had
exactly this drift before this checker), or a flag/field pair survives in
one place after the other was renamed.  The single source of truth is the
declarative registry in ``src/repro/launch/flags.py``:

* ``FIELD_FLAGS``    — ``"Cls.field" -> "--flag"`` for every field the
  CLI reaches;
* ``INTERNAL_FIELDS`` — ``"Cls.field" -> reason`` for fields deliberately
  not CLI-reachable.

The checker cross-references the dataclass definitions (by AST — nothing
is imported), the registry, the ``add_argument`` calls in ``flags.py``,
and the serving docs: every field must appear in exactly one registry,
every registry entry must name a real field, every mapped flag must be
registered, and every CLI-reachable field must be mentioned in
``docs/SERVING.md`` or ``docs/PLANS.md``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.core import Finding, RepoContext, checker

FLAGS_REL = "src/repro/launch/flags.py"
DOCS_REL = ("docs/SERVING.md", "docs/PLANS.md")
# the config dataclasses under contract: class name -> defining module
CONFIG_CLASSES: Dict[str, str] = {
    "ServeConfig": "src/repro/serve/engine.py",
    "FrontendConfig": "src/repro/serve/frontend.py",
    "ModelOptions": "src/repro/models/transformer.py",
}


def _class_fields(tree: ast.AST, cls: str) -> List[Tuple[str, int]]:
    """(field, lineno) for each annotated dataclass field of ``cls``."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls:
            return [
                (stmt.target.id, stmt.lineno)
                for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
            ]
    return []


def _str_dict(tree: ast.AST, name: str) -> Optional[Dict[str, str]]:
    """A module-level ``NAME = {"str": "str", ...}`` literal, or None."""
    for node in tree.body if hasattr(tree, "body") else []:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == name \
                and isinstance(node.value, ast.Dict):
            out = {}
            for k, v in zip(node.value.keys, node.value.values):
                if isinstance(k, ast.Constant) and isinstance(v, ast.Constant):
                    out[str(k.value)] = str(v.value)
            return out
    return None


def _registered_flags(tree: ast.AST) -> Set[str]:
    flags: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "add_argument":
            for arg in node.args:
                if isinstance(arg, ast.Constant) and isinstance(arg.value, str) \
                        and arg.value.startswith("--"):
                    flags.add(arg.value)
    return flags


@checker("config-surface", scope=tuple(CONFIG_CLASSES.values()) + (FLAGS_REL,),
         repo_level=True)
def check(ctx: RepoContext) -> Iterator[Finding]:
    """Cross-check config dataclasses against the flag registry and docs."""
    flags_tree = ctx.parse(FLAGS_REL)
    if flags_tree is None:
        yield Finding(
            "config-surface", FLAGS_REL, 1,
            f"{FLAGS_REL} is missing or unparseable; it must declare "
            "FIELD_FLAGS / INTERNAL_FIELDS — the registry this checker "
            "(and the serving CLI) treat as the single source of truth")
        return
    field_flags = _str_dict(flags_tree, "FIELD_FLAGS")
    internal = _str_dict(flags_tree, "INTERNAL_FIELDS")
    for name, table in (("FIELD_FLAGS", field_flags),
                        ("INTERNAL_FIELDS", internal)):
        if table is None:
            yield Finding(
                "config-surface", FLAGS_REL, 1,
                f"{FLAGS_REL} does not declare a literal {name} dict")
    if field_flags is None or internal is None:
        return
    registered = _registered_flags(flags_tree)
    docs = "\n".join(ctx.read(rel) or "" for rel in DOCS_REL)

    real_fields: Set[str] = set()
    for cls, rel in CONFIG_CLASSES.items():
        tree = ctx.parse(rel)
        if tree is None:
            yield Finding("config-surface", rel, 1,
                          f"cannot parse {rel} to find {cls}")
            continue
        fields = _class_fields(tree, cls)
        if not fields:
            yield Finding("config-surface", rel, 1,
                          f"{cls} not found (or has no annotated fields) "
                          f"in {rel}")
            continue
        for field, lineno in fields:
            key = f"{cls}.{field}"
            real_fields.add(key)
            in_flags, in_internal = key in field_flags, key in internal
            if in_flags and in_internal:
                yield Finding(
                    "config-surface", FLAGS_REL, 1,
                    f"{key} appears in both FIELD_FLAGS and INTERNAL_FIELDS; "
                    "a field is CLI-reachable or internal, not both")
            elif not in_flags and not in_internal:
                yield Finding(
                    "config-surface", rel, lineno,
                    f"{key} is neither reachable from a serve CLI flag "
                    "(FIELD_FLAGS) nor marked internal (INTERNAL_FIELDS) in "
                    f"{FLAGS_REL}; new config knobs must be wired through "
                    "launch/serve.py or explicitly opted out")
            if in_flags:
                flag = field_flags[key]
                if flag not in registered:
                    yield Finding(
                        "config-surface", FLAGS_REL, 1,
                        f"FIELD_FLAGS maps {key} to {flag!r} but no "
                        f"add_argument({flag!r}, ...) exists in {FLAGS_REL}")
                if field not in docs and flag not in docs:
                    yield Finding(
                        "config-surface", rel, lineno,
                        f"CLI-reachable field {key} (flag {flag}) is "
                        f"mentioned in neither of {DOCS_REL}; document the "
                        "knob where operators will look for it")
    for key in list(field_flags) + list(internal):
        if key not in real_fields:
            cls = key.split(".", 1)[0]
            if cls in CONFIG_CLASSES:
                yield Finding(
                    "config-surface", FLAGS_REL, 1,
                    f"registry entry {key} names a field that no longer "
                    "exists on its dataclass; delete or rename the entry")
