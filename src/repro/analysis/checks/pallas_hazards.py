"""pallas-hazards: lowering traps and dense-gather regressions in kernels.

Two invariant classes, both learned the hard way:

* ``pl.program_id`` has **no lowering rule inside the nested cond jaxpr**
  that a ``pl.when`` body becomes (PR 8 hit this in interpret mode when
  an int8 scale lookup moved inside the skip-dead-blocks cond).  The
  checker flags ``program_id`` calls — and subscripts indexed by a name
  bound from ``program_id`` — lexically inside a ``@pl.when(...)`` body
  (or a ``jax.lax.cond`` branch function).  Hoist the lookup above the
  cond; the value is loop-invariant per grid step anyway.
* The paged-attention kernels exist to be **gather-free** (PR 5): no
  dense materialized view of pooled KV.  ``jnp.take`` /
  ``jnp.take_along_axis`` / ``.take(...)`` in a ``kernels/*/kernel.py``
  or ``ops.py`` reintroduces exactly the traffic class the streaming
  kernel eliminated — gathers belong in ``ref.py`` oracles only.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from repro.analysis.core import Finding, RepoContext, SourceFile, checker

SCOPE = ("src/repro/kernels/*/kernel.py", "src/repro/kernels/*/ops.py")


def _dotted(node: ast.AST) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_program_id_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and _dotted(node.func).endswith("program_id"))


def _when_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call) and _dotted(dec.func).endswith(".when"):
            return True
    return False


def _cond_branches(node: ast.Call) -> List[ast.expr]:
    """Branch callables of a ``lax.cond``/``jax.lax.cond`` call."""
    if _dotted(node.func).endswith("lax.cond"):
        return list(node.args[1:])
    return []


def _pid_bound_names(tree: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and _is_program_id_call(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Tuple):
            for t, v in zip(
                (node.targets[0].elts
                 if isinstance(node.targets[0], ast.Tuple) else []),
                node.value.elts,
            ):
                if isinstance(t, ast.Name) and _is_program_id_call(v):
                    names.add(t.id)
    return names


def _scan_cond_body(body_nodes: List[ast.AST], pid_names: Set[str],
                    sf: SourceFile, context: str) -> Iterator[Finding]:
    for stmt in body_nodes:
        for node in ast.walk(stmt):
            if _is_program_id_call(node):
                yield Finding(
                    "pallas-hazards", sf.rel, node.lineno,
                    f"pl.program_id called inside {context}: program_id has "
                    "no lowering rule in nested cond jaxprs (interpret mode "
                    "included) — hoist the call above the cond")
            elif isinstance(node, ast.Subscript):
                idx_names = {n.id for n in ast.walk(node.slice)
                             if isinstance(n, ast.Name)}
                hit = idx_names & pid_names
                if hit:
                    yield Finding(
                        "pallas-hazards", sf.rel, node.lineno,
                        f"subscript indexed by program_id-bound name(s) "
                        f"{sorted(hit)} inside {context}: the lookup lowers "
                        "through the nested cond jaxpr where program_id is "
                        "unavailable — hoist it above the cond (PR 8 "
                        "regression class)")


_GATHERS = ("take", "take_along_axis")


@checker("pallas-hazards", scope=SCOPE)
def check(sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
    """Flag program_id-in-cond lowering traps and dense gathers in
    kernel/ops modules."""
    pid_names = _pid_bound_names(sf.tree)
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.FunctionDef) and _when_decorated(node):
            yield from _scan_cond_body(node.body, pid_names, sf,
                                       "a pl.when body")
        elif isinstance(node, ast.Call):
            for branch in _cond_branches(node):
                if isinstance(branch, ast.Lambda):
                    yield from _scan_cond_body([branch.body], pid_names, sf,
                                               "a lax.cond branch")
            fn = _dotted(node.func)
            leaf = fn.rsplit(".", 1)[-1]
            if leaf in _GATHERS and ("." in fn):
                yield Finding(
                    "pallas-hazards", sf.rel, node.lineno,
                    f"{fn}(...) materializes a gathered view inside a "
                    "kernel/ops module; the paged kernels are gather-free "
                    "by contract — stream through the block table instead "
                    "(gathers belong in ref.py oracles)")
