"""trace-purity: no ambient wall clock or RNG inside traced serving paths.

Everything under ``src/repro/{models,kernels,serve,runtime}`` executes
inside (or feeds) jitted/replayed code: the traffic harness replays whole
serving runs on a virtual clock, the serve engine's outputs must be a
pure function of (requests, seed, plan), prefix reuse replays pooled KV
verbatim, and the runtime recovery loop (``runtime/fault.py``) must be
replayable under the same discipline.  A stray ``time.time()`` or
``np.random.*`` call breaks all of that invisibly — PR 6 had to hunt
down every internal wall-clock read to make replay deterministic.
Clocks are injected (``ServeEngine(clock=)``, ``run_with_restarts
(clock=)``) and randomness flows through explicit ``jax.random`` keys or
caller-owned ``numpy`` Generators.

The single sanctioned wall-clock entry point is
``src/repro/serve/clock.py`` (the injected-clock plumbing), which carries
its own justified suppression.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Set

from repro.analysis.core import Finding, RepoContext, SourceFile, checker

SCOPE = ("src/repro/models/*", "src/repro/kernels/*", "src/repro/serve/*",
         "src/repro/runtime/*")

# module attribute accesses that read ambient time/randomness.  Key: the
# *real* module name (aliases are resolved from the file's imports);
# value: banned attribute names, or "*" for the whole namespace.
BANNED_ATTRS: Dict[str, Set[str]] = {
    "time": {"time", "time_ns", "monotonic", "monotonic_ns", "perf_counter",
             "perf_counter_ns", "process_time", "sleep", "localtime",
             "gmtime"},
    "datetime": {"now", "utcnow", "today"},  # via datetime.datetime.now etc.
    "numpy.random": {"*"},
    "random": {"*"},
    "secrets": {"*"},
    "uuid": {"uuid1", "uuid4"},
}
BANNED_OS = {"urandom", "getrandom"}
# direct ``from time import time`` style imports of banned names
BANNED_FROM = {("time", "time"), ("time", "monotonic"),
               ("time", "perf_counter"), ("random", "random"),
               ("random", "randint"), ("random", "choice"),
               ("random", "shuffle"), ("random", "seed")}


def _import_aliases(tree: ast.AST) -> Dict[str, str]:
    """Map local names to the real module paths they stand for."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an attribute chain (``np.random.rand`` ->
    "np.random.rand"); "" when the chain roots in a call/subscript."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


@checker("trace-purity", scope=SCOPE)
def check(sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
    """Ban wall-clock/ambient-RNG reads in models/kernels/serve."""
    aliases = _import_aliases(sf.tree)
    for local, real in aliases.items():
        mod, _, attr = real.rpartition(".")
        if (mod, attr) in BANNED_FROM:
            # the import itself is the hazard: a bare ``time()`` call site
            # is indistinguishable from any other callable afterwards
            yield Finding(
                "trace-purity", sf.rel, 1,
                f"'from {mod} import {attr}' pulls ambient "
                f"{'time' if mod == 'time' else 'randomness'} into a traced "
                f"path; inject a clock/PRNG key instead (docs/ANALYSIS.md)")
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Attribute):
            continue
        dotted = _dotted(node)
        if not dotted:
            continue
        head, rest = dotted.split(".", 1) if "." in dotted else (dotted, "")
        real = aliases.get(head, head)
        chain = f"{real}.{rest}" if rest else real
        # normalize datetime.datetime.now -> datetime.now for matching
        chain = chain.replace("datetime.datetime.", "datetime.")
        for mod, banned in BANNED_ATTRS.items():
            prefix = mod + "."
            if not chain.startswith(prefix):
                continue
            attr = chain[len(prefix):].split(".")[0]
            if "*" in banned or attr in banned:
                what = ("wall clock" if mod in ("time", "datetime")
                        else "ambient randomness")
                yield Finding(
                    "trace-purity", sf.rel, node.lineno,
                    f"{chain} reads {what} inside a traced serving path; "
                    "inject the clock (ServeEngine(clock=)) or thread an "
                    "explicit jax.random key / numpy Generator "
                    "(docs/ANALYSIS.md §trace-purity)")
                break
        if chain.startswith("os.") and chain.split(".")[1] in BANNED_OS:
            yield Finding(
                "trace-purity", sf.rel, node.lineno,
                f"{chain} reads OS entropy inside a traced serving path; "
                "thread an explicit PRNG key instead")
