"""determinism-gates: replayed-KV features stay behind their gate helpers.

Two serving features replay pooled KV bytes computed under an earlier
batch packing: prefix reuse (``RadixPrefixTree``) and int8 KV
quantization (``init_paged_quant_cache``).  Both are only sound when
interned KV is a pure function of the token path, and the repo has
exactly two helpers that encode that discipline —
``_kv_deterministic(model)`` and ``kv_quant_reject_reason(model,
kv_block_size)`` in ``serve/engine.py`` (DESIGN.md §Numerics and
parity).  A new call site that constructs the prefix tree or a quantized
pool without consulting a gate silently reintroduces
admission-history-dependent outputs.

The rule: any module in scope that *calls* a gated constructor must also
reference one of the gate helpers.  Modules that merely define the
constructor (``prefix_tree.py``, ``models/attention.py``) are exempt —
defining the mechanism is not enabling it.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from repro.analysis.core import Finding, RepoContext, SourceFile, checker

SCOPE = ("src/repro/serve/*", "src/repro/models/*", "src/repro/launch/*")
# constructor name -> the feature it enables
GATED = {
    "RadixPrefixTree": "prefix reuse",
    "init_paged_quant_cache": "int8 KV quantization",
}
GATES = ("_kv_deterministic", "kv_quant_reject_reason")


def _dotted_leaf(node: ast.AST) -> str:
    while isinstance(node, ast.Attribute):
        return node.attr
    return node.id if isinstance(node, ast.Name) else ""


def _defined_names(tree: ast.AST) -> Set[str]:
    return {n.name for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.ClassDef))}


def _referenced_names(tree: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
        elif isinstance(node, ast.ImportFrom):
            names.update(a.name for a in node.names)
    return names


@checker("determinism-gates", scope=SCOPE)
def check(sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
    """Flag gated-constructor calls in modules that consult no gate."""
    defined = _defined_names(sf.tree)
    referenced = _referenced_names(sf.tree)
    has_gate = any(g in referenced for g in GATES)
    calls: List[ast.Call] = [
        n for n in ast.walk(sf.tree)
        if isinstance(n, ast.Call) and _dotted_leaf(n.func) in GATED
    ]
    for call in calls:
        name = _dotted_leaf(call.func)
        if name in defined:
            continue  # the defining module exercising its own mechanism
        if not has_gate:
            yield Finding(
                "determinism-gates", sf.rel, call.lineno,
                f"{name}(...) enables {GATED[name]} but this module never "
                f"consults a determinism gate ({' / '.join(GATES)} in "
                "serve/engine.py); replayed pooled KV must be proven a pure "
                "function of the token path before the feature turns on "
                "(DESIGN.md §Numerics and parity)")
