"""Built-in checkers.  Importing this package registers all of them; a new
checker is one module with a ``@checker(...)``-decorated function plus an
import line here (docs/ANALYSIS.md §Adding a checker)."""
from repro.analysis.checks import (  # noqa: F401  (imported for registration)
    config_surface,
    determinism_gates,
    kernel_contract,
    pallas_hazards,
    site_grammar,
    swallowed_exceptions,
    trace_purity,
)
