"""swallowed-exceptions: no silently discarded failures in fault-bearing code.

The serving and runtime layers are exactly the places that *handle*
faults on purpose — quarantine, restart, retry — which makes a handler
that swallows an exception without acting on it doubly dangerous there:
a ``except Exception: pass`` in the supervisor or the recovery loop
converts a containment bug into silent corruption (a leaked KV block, a
half-committed step) that only the ``audit()`` cross-checks might catch
much later.  This checker bans, under ``src/repro/{serve,runtime}``:

* **bare ``except:``** — always, regardless of body (it catches
  ``KeyboardInterrupt``/``SystemExit`` too, which nothing here should);
* **no-op broad handlers** — ``except Exception`` / ``except
  BaseException`` (directly or inside a tuple) whose body does nothing:
  only ``pass``, ``...``, bare ``continue``, or docstring-style constant
  expressions.

A broad handler that *does something* — logs, re-raises, counts,
restores state — is the legitimate pattern (``run_with_restarts`` treats
any step failure as recoverable and says so) and is not flagged.
Intentional narrow swallows of *specific* exception types
(``except KeyError: pass``) are likewise fine: naming the type is the
evidence the author thought about what is being discarded.

Suppress (with justification) via the standard mechanism:
``# repro-lint: disable=swallowed-exceptions -- why``.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import Finding, RepoContext, SourceFile, checker

SCOPE = ("src/repro/serve/*", "src/repro/runtime/*")

BROAD = {"Exception", "BaseException"}


def _names(node: ast.expr) -> Iterator[str]:
    """Exception-class names mentioned by an ``except`` clause type."""
    if isinstance(node, ast.Tuple):
        for elt in node.elts:
            yield from _names(elt)
    elif isinstance(node, ast.Name):
        yield node.id
    elif isinstance(node, ast.Attribute):
        yield node.attr  # e.g. builtins.Exception


def _is_noop(body) -> bool:
    """True when a handler body discards the exception without acting:
    every statement is ``pass``, ``...``/constant expression, or a bare
    ``continue``."""
    for stmt in body:
        if isinstance(stmt, (ast.Pass, ast.Continue)):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
            continue
        return False
    return True


@checker("swallowed-exceptions", scope=SCOPE)
def check(sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
    """Ban bare ``except:`` and no-op broad handlers in serve/runtime."""
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            yield Finding(
                "swallowed-exceptions", sf.rel, node.lineno,
                "bare 'except:' catches everything including "
                "KeyboardInterrupt/SystemExit; name the exception types "
                "this fault path is designed to contain "
                "(docs/ANALYSIS.md §swallowed-exceptions)")
            continue
        if not any(n in BROAD for n in _names(node.type)):
            continue
        if _is_noop(node.body):
            yield Finding(
                "swallowed-exceptions", sf.rel, node.lineno,
                "broad exception handler silently discards the failure; "
                "in fault-bearing code a swallowed error becomes invisible "
                "corruption — log it, count it, re-raise, or narrow the "
                "type (docs/ANALYSIS.md §swallowed-exceptions)")
