"""Framework: findings, suppressions, the checker registry, and the runner.

A *checker* is a named function registered via :func:`checker`.  Two
shapes exist:

* **per-file** — ``fn(sf: SourceFile, ctx: RepoContext) -> Iterable[Finding]``,
  invoked for every collected ``.py`` file whose repo-relative path matches
  the checker's ``scope`` globs;
* **repo-level** (``repo_level=True``) — ``fn(ctx) -> Iterable[Finding]``,
  invoked once per run when at least one collected file matches ``scope``
  (these checkers cross-reference fixed locations: the kernels tree, the
  config dataclasses, the flag registry).

Suppression comments (docs/ANALYSIS.md §Suppressions)::

    # repro-lint: disable=<check>[,<check>...] [-- justification]

On a code line the suppression applies to findings anchored to that line;
on a line of its own it applies to the whole file.  ``disable=all``
covers every check.  ``--strict`` turns justification-less suppressions
and unknown check names into findings themselves, so the suppression
surface cannot rot silently.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
import re
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,-]+)(?:\s+--\s*(\S.*))?"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    check: str
    path: str  # repo-root-relative, posix separators
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.check}] {self.message}"

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    checks: Tuple[str, ...]
    file_level: bool
    justification: Optional[str]


class SourceFile:
    """One collected ``.py`` file: text, parsed tree, suppressions."""

    def __init__(self, abspath: str, rel: str):
        self.abspath = abspath
        self.rel = rel
        with open(abspath, encoding="utf-8") as f:
            self.text = f.read()
        self.lines = self.text.splitlines()
        self.suppressions: List[Suppression] = []
        for i, line in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(line)
            if m:
                checks = tuple(c.strip() for c in m.group(1).split(",") if c.strip())
                self.suppressions.append(Suppression(
                    i, checks, line.lstrip().startswith("#"), m.group(2)
                ))
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree: Optional[ast.AST] = ast.parse(self.text, filename=rel)
        except SyntaxError as e:
            self.tree = None
            self.parse_error = e

    def suppressed(self, check: str, line: int) -> bool:
        for s in self.suppressions:
            if check in s.checks or "all" in s.checks:
                if s.file_level or s.line == line:
                    return True
        return False


@dataclasses.dataclass
class _Checker:
    name: str
    fn: Callable
    scope: Tuple[str, ...]
    repo_level: bool
    doc: str


CHECKERS: Dict[str, _Checker] = {}


def checker(name: str, scope: Sequence[str], repo_level: bool = False):
    """Register a checker under ``name`` for files matching ``scope``
    (fnmatch globs over repo-relative posix paths)."""

    def deco(fn):
        CHECKERS[name] = _Checker(name, fn, tuple(scope), repo_level,
                                  (fn.__doc__ or "").strip().splitlines()[0]
                                  if fn.__doc__ else "")
        return fn

    return deco


def _in_scope(rel: str, scope: Tuple[str, ...]) -> bool:
    return any(fnmatch.fnmatchcase(rel, pat) for pat in scope)


def find_repo_root(start: str) -> str:
    """Nearest ancestor carrying ``pyproject.toml`` (the linter resolves
    cross-file anchors — kernels tree, docs, flag registry — from here)."""
    p = os.path.abspath(start)
    if os.path.isfile(p):
        p = os.path.dirname(p)
    while True:
        if os.path.exists(os.path.join(p, "pyproject.toml")):
            return p
        parent = os.path.dirname(p)
        if parent == p:
            return os.path.abspath(start if os.path.isdir(start)
                                   else os.path.dirname(start))
        p = parent


_SKIP_DIRS = {".git", "__pycache__", "artifacts", ".github", ".ruff_cache",
              "build", "dist"}


class RepoContext:
    """Repo-wide state shared by every checker in one run: the root, the
    collected files, and lazily parsed anchor files."""

    def __init__(self, root: str, files: List[SourceFile]):
        self.root = root
        self.files = files
        self.by_rel: Dict[str, SourceFile] = {f.rel: f for f in files}
        self._parsed: Dict[str, Optional[ast.AST]] = {}
        self._text: Dict[str, Optional[str]] = {}
        self.extra_findings: List[Finding] = []

    def rel(self, abspath: str) -> str:
        return os.path.relpath(abspath, self.root).replace(os.sep, "/")

    def read(self, rel: str) -> Optional[str]:
        """Text of a repo file by relative path (None if absent)."""
        if rel not in self._text:
            path = os.path.join(self.root, rel)
            if os.path.exists(path):
                with open(path, encoding="utf-8") as f:
                    self._text[rel] = f.read()
            else:
                self._text[rel] = None
        return self._text[rel]

    def parse(self, rel: str) -> Optional[ast.AST]:
        """Parsed AST of a repo ``.py`` file (collected or not); None if
        the file is absent or unparseable."""
        if rel not in self._parsed:
            text = self.read(rel)
            try:
                self._parsed[rel] = ast.parse(text, filename=rel) if text is not None else None
            except SyntaxError:
                self._parsed[rel] = None
        return self._parsed[rel]

    def scoped(self, scope: Tuple[str, ...]) -> List[SourceFile]:
        return [f for f in self.files if _in_scope(f.rel, scope)]


def collect_files(root: str, targets: Sequence[str]) -> List[SourceFile]:
    out: List[SourceFile] = []
    seen: Set[str] = set()
    for t in targets:
        t = os.path.abspath(t)
        if os.path.isfile(t):
            paths = [t]
        else:
            paths = []
            for dirpath, dirnames, filenames in os.walk(t):
                dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
                paths += [os.path.join(dirpath, f) for f in sorted(filenames)
                          if f.endswith(".py")]
        for p in paths:
            if p not in seen:
                seen.add(p)
                out.append(SourceFile(p, os.path.relpath(p, root).replace(os.sep, "/")))
    return out


def _strict_findings(ctx: RepoContext) -> List[Finding]:
    """Under ``--strict``, police the suppression surface itself."""
    out = []
    known = set(CHECKERS) | {"all", "parse", "suppression"}
    for f in ctx.files:
        for s in f.suppressions:
            unknown = [c for c in s.checks if c not in known]
            if unknown:
                out.append(Finding(
                    "suppression", f.rel, s.line,
                    f"suppression names unknown check(s) {unknown}; known: "
                    f"{', '.join(sorted(CHECKERS))}"))
            if not s.justification:
                out.append(Finding(
                    "suppression", f.rel, s.line,
                    "suppression without justification; append "
                    "'-- <one-line reason>' (required under --strict)"))
    return out


def run_analysis(targets: Sequence[str], root: Optional[str] = None,
                 disable: Sequence[str] = (), strict: bool = False,
                 ) -> Tuple[List[Finding], Dict[str, object]]:
    """Run every registered checker over ``targets``.

    Returns ``(findings, stats)`` — findings already filtered through
    suppression comments and sorted by (path, line, check).
    """
    if not targets:
        raise ValueError("no targets: pass at least one file or directory")
    root = os.path.abspath(root) if root else find_repo_root(targets[0])
    files = collect_files(root, targets)
    ctx = RepoContext(root, files)

    raw: List[Finding] = []
    for f in files:
        if f.parse_error is not None:
            raw.append(Finding("parse", f.rel, f.parse_error.lineno or 1,
                               f"syntax error: {f.parse_error.msg}"))
    active = [c for name, c in CHECKERS.items() if name not in disable]
    for c in active:
        if c.repo_level:
            if ctx.scoped(c.scope) or not c.scope:
                raw.extend(c.fn(ctx))
        else:
            for f in ctx.scoped(c.scope):
                if f.tree is None:
                    continue
                raw.extend(c.fn(f, ctx))
    if strict:
        raw.extend(_strict_findings(ctx))

    findings = []
    for fd in raw:
        sf = ctx.by_rel.get(fd.path)
        if sf is not None and fd.check != "suppression" and sf.suppressed(fd.check, fd.line):
            continue
        findings.append(fd)
    findings.sort(key=lambda fd: (fd.path, fd.line, fd.check))

    counts: Dict[str, int] = {}
    for fd in findings:
        counts[fd.check] = counts.get(fd.check, 0) + 1
    stats = {
        "root": root,
        "n_files": len(files),
        "checkers": sorted(c.name for c in active),
        "counts": counts,
        "strict": strict,
    }
    return findings, stats
