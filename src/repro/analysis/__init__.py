"""Repo-specific static analysis for the JAX/Pallas serving stack.

The serving stack rests on invariants that unit tests only probe at a few
points: traced paths must not read wall clocks or ambient RNG (replay
determinism), Pallas kernel bodies must not hide ``program_id``-dependent
lookups inside ``pl.when`` (no lowering rule under nested conds), kernel
subpackages must keep the kernel/ops/ref contract, site-name literals must
follow the ``L{li}.{kind}.{op}`` grammar of ``core/plan.py``, every serve
config field must stay reachable from the CLI, and determinism-gated
features must actually call their gates.  ``repro.analysis`` checks all of
that at review time with stdlib ``ast`` — no third-party dependencies —
and runs in CI before the test matrix (docs/ANALYSIS.md).

Usage::

    PYTHONPATH=src python -m repro.analysis src/ --strict

Suppressions (docs/ANALYSIS.md §Suppressions)::

    x = time.time()  # repro-lint: disable=trace-purity -- why it is OK
    # repro-lint: disable=site-grammar -- file-level, from its own line

``--strict`` additionally rejects suppressions without a ``-- reason``
and suppressions naming unknown checks.
"""
from repro.analysis.core import (  # noqa: F401  (public API re-exports)
    CHECKERS, Finding, RepoContext, SourceFile, checker, run_analysis,
)

# importing the package registers every built-in checker
from repro.analysis import checks  # noqa: F401,E402

__all__ = [
    "CHECKERS", "Finding", "RepoContext", "SourceFile", "checker",
    "run_analysis",
]
