"""Mesh-agnostic, atomic, async checkpointing.

Layout: ``<dir>/step_<n>/`` holding one ``.npy`` per pytree leaf (flattened
path as filename) plus ``manifest.json`` (treedef, shapes, logical dtypes,
user metadata).  Writes go to ``step_<n>.tmp`` and are atomically renamed —
a crash mid-write never corrupts the latest valid checkpoint.

**Elastic restore**: leaves are stored as *full logical arrays* (gathered
from devices), so a checkpoint written on one mesh restores onto any other —
``restore_checkpoint(..., shardings=...)`` device_puts each leaf with the
new mesh's NamedSharding.  This is what lets a 512-chip job resume on 256
chips after losing a pod (see ``repro.runtime.elastic``).

bfloat16 (an ml_dtypes extension dtype) is stored as a uint16 view with the
logical dtype recorded in the manifest — ``.npy`` stays portable.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

_MANIFEST = "manifest.json"
_VIEW = {"bfloat16": "uint16", "float8_e4m3fn": "uint8", "float8_e5m2": "uint8"}


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def _save_tree(tree, out_dir: str) -> Dict[str, Dict[str, str]]:
    leaves: Dict[str, Dict[str, str]] = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        name = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical in _VIEW:
            arr = arr.view(_VIEW[logical])
        np.save(os.path.join(out_dir, name + ".npy"), arr, allow_pickle=False)
        leaves[name] = {"dtype": logical}
    return leaves


def save_checkpoint(ckpt_dir: str, step: int, tree, metadata: Optional[Dict] = None) -> str:
    """Atomic synchronous save; returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _save_tree(tree, tmp)
    manifest = {"step": step, "leaves": leaves, "metadata": metadata or {}}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
                steps.append(int(name[len("step_"):]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``.

    ``target_tree`` may hold arrays or ShapeDtypeStructs (its treedef and
    leaf dtypes are the contract).  ``shardings``: optional matching pytree
    of NamedShardings — each leaf is device_put with it (elastic re-shard).
    Returns (tree, metadata).
    """
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, _MANIFEST)) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
        assert len(sh_leaves) == len(flat), "shardings tree mismatch"
    out = []
    for i, (path, leaf) in enumerate(flat):
        name = _path_str(path)
        info = manifest["leaves"][name]
        arr = np.load(os.path.join(final, name + ".npy"))
        logical = info["dtype"]
        if logical in _VIEW:
            arr = arr.view(jnp.dtype(logical))
        expect = tuple(leaf.shape)
        assert tuple(arr.shape) == expect, (name, arr.shape, expect)
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]


class CheckpointManager:
    """Async save + retention.  ``save`` snapshots to host synchronously
    (cheap relative to a step) and writes files on a background thread so
    the train loop overlaps I/O with compute; ``wait()`` joins in-flight
    writes (called before process exit and in tests)."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_write: bool = True):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.async_write = async_write
        self._lock = threading.Lock()
        self._inflight: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, tree, metadata: Optional[Dict] = None):
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def write():
            with self._lock:
                save_checkpoint(self.ckpt_dir, step, host_tree, metadata)
                self._gc()

        if self.async_write:
            self.wait()
            self._inflight = threading.Thread(target=write, daemon=True)
            self._inflight.start()
        else:
            write()

    def wait(self):
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.ckpt_dir)

    def restore(self, step: int, target_tree, shardings=None):
        self.wait()
        return restore_checkpoint(self.ckpt_dir, step, target_tree, shardings)

    def _gc(self):
        steps = sorted(
            int(n[len("step_"):]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
