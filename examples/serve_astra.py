"""End-to-end serving driver example (the paper's workload: inference).

Serves a small model with batched requests through the KV-cache decode path
under the ASTRA int8 expectation mode, compares generations against the
fp32 reference, and prints the modeled photonic hardware cost per request.

  PYTHONPATH=src python examples/serve_astra.py [--arch stablelm-1.6b]
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "stablelm-1.6b", "--reduced",
        "--batch", "4", "--prompt-len", "32", "--gen", "16",
        "--mode", "int8", "--compare-exact",
    ]
    main(argv)
