"""End-to-end serving example (the paper's workload: inference).

Drives the continuous-batching serve engine (``repro.serve``) with a mixed
prompt-length request stream — short and long prompts share one running
batch, joining and leaving at chunk granularity — under the ASTRA int8
expectation mode, compares generations against the fp32 reference, and
prints the modeled photonic hardware cost per request (attributed per
GEMM site).  Any flag of ``repro.launch.serve`` works — notably
``--plan mixed --calibrate`` for the per-site execution-plan path
(int8 attention qk/pv + stochastic-stream projections, PTQ-calibrated;
docs/PLANS.md), ``--kv-block-size`` / ``--no-prefix-cache`` for the
paged KV cache with radix-tree prefix reuse (docs/SERVING.md), and
``--prefill-chunk-tokens`` for the chunked-prefill scheduler that
interleaves prompt chunks with decode so long prompts never stall
in-flight requests (docs/SERVING.md §Scheduling), and ``--attn-impl
flash`` for the Pallas attention kernels — gather-free streaming decode
over the paged pool (docs/SERVING.md §Decode-attention memory model).

  PYTHONPATH=src python examples/serve_astra.py [--arch stablelm-1.6b]
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "stablelm-1.6b", "--reduced",
        "--batch", "6", "--prompt-mix", "16,32,64", "--gen", "16",
        "--max-slots", "4", "--chunk-steps", "8", "--kv-block-size", "16",
        "--mode", "int8", "--compare-exact",
    ]
    main(argv)
