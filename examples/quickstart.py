"""Quickstart: ASTRA stochastic-photonic inference in 60 seconds.

Builds a tiny GQA transformer, runs the same forward pass under the three
ASTRA numeric modes (exact fp32 / int8 expectation / bit-true 128-bit
stochastic streams), shows they agree, and prints the modeled photonic
latency/energy for the workload.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.core.energy import AstraChipConfig
from repro.core.simulator import simulate
from repro.models.model import Model
from repro.models.transformer import ModelOptions, forward


def main():
    cfg = dataclasses.replace(get_arch("qwen1.5-0.5b").reduced(), dtype="float32")
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model})")
    key = jax.random.PRNGKey(0)
    model = Model(cfg, ModelOptions())
    params = model.init(key)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab)

    logits = {}
    for mode in ("exact", "int8", "sc"):
        out, _, _ = forward(params, tokens, cfg, ModelOptions(cc=ComputeConfig(mode)))
        logits[mode] = np.asarray(out, np.float32)
        if mode != "exact":
            ref = logits["exact"]
            rel = np.linalg.norm(logits[mode] - ref) / np.linalg.norm(ref)
            agree = (logits[mode].argmax(-1) == ref.argmax(-1)).mean()
            print(f"{mode:6s}: rel logits err {rel * 100:.2f}%  "
                  f"greedy-token agreement {agree * 100:.1f}%")

    chip = AstraChipConfig()
    rep = simulate(cfg, chip, seq=32, batch=2)
    print(f"\nASTRA chip model ({chip.total_vdpes} VDPEs x {chip.lanes} OSSMs, "
          f"{chip.peak_macs_per_s * 2 / 1e12:.0f} TOPS peak):")
    print(f"  latency {rep.latency_s * 1e6:9.1f} us")
    print(f"  energy  {rep.total_energy_j * 1e6:9.1f} uJ  "
          f"({rep.energy_per_mac_j * 1e15:.0f} fJ/MAC incl. electronics)")
    top = sorted(rep.energy_j.items(), key=lambda kv: -kv[1])[:4]
    print("  top components: " + ", ".join(f"{k} {100 * v / rep.total_energy_j:.0f}%"
                                           for k, v in top))


if __name__ == "__main__":
    main()
