"""Train a tiny model for a few hundred steps with full fault tolerance.

Demonstrates the production train loop end-to-end on CPU: deterministic
data pipeline, AdamW + cosine schedule, async atomic checkpoints, an
injected failure at step 120, automatic restore, and a bit-exact resumed
trajectory (compare the logged losses around the fault).

  PYTHONPATH=src python examples/train_tiny.py
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "qwen1.5-0.5b", "--reduced",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--lr", "2e-3", "--ckpt-dir", "/tmp/repro_train_tiny",
        "--ckpt-every", "50", "--fail-at", "120", "--log-every", "20",
    ]
    main(argv)
