"""Fig. 6 reproduction: energy vs CPU/GPU/TPU/FPGA/TransPIM/LT/TRON/SCONNA.

Normalized-to-CPU energy per inference for the five paper models.
Claims under test: ASTRA >=1.3x lower energy than every accelerator and
>1000x lower than CPU/GPU/TPU.
"""
from __future__ import annotations

from repro.configs import PAPER_MODELS, PAPER_SEQ_LEN, get_arch
from repro.core.baselines import BASELINES, simulate_baseline
from repro.core.energy import AstraChipConfig
from repro.core.simulator import simulate

PLATFORMS = ("cpu", "gpu", "tpu")


def run(log=print):
    chip = AstraChipConfig()
    names = list(BASELINES) + ["astra"]
    log("# Fig6: energy per inference, normalized to CPU (lower is better)")
    log("energy_comparison,model," + ",".join(names))
    out = {}
    worst_acc, worst_plat = float("inf"), float("inf")
    for model in PAPER_MODELS:
        cfg = get_arch(model)
        seq = PAPER_SEQ_LEN[model]
        astra = simulate(cfg, chip, seq=seq)
        e = {"astra": astra.total_energy_j}
        for b, spec in BASELINES.items():
            e[b] = simulate_baseline(spec, cfg, seq).total_energy_j
        cpu = e["cpu"]
        log(f"energy_comparison,{model}," +
            ",".join(f"{e[n] / cpu:.3e}" for n in names))
        for b in BASELINES:
            ratio = e[b] / e["astra"]
            if b in PLATFORMS:
                worst_plat = min(worst_plat, ratio)
            else:
                worst_acc = min(worst_acc, ratio)
        out[model] = {n: e[n] for n in names}
    ok = worst_acc >= 1.3 and worst_plat > 1000.0
    log(f"energy_comparison,worst_accel_ratio={worst_acc:.2f}(>=1.3),"
        f"worst_platform_ratio={worst_plat:.0f}(>1000),{'PASS' if ok else 'FAIL'}")
    return {"energies_J": out, "worst_accel_ratio": worst_acc,
            "worst_platform_ratio": worst_plat, "claim_pass": bool(ok)}


if __name__ == "__main__":
    run()
