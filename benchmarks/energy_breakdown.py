"""Fig. 5 reproduction: energy breakdown across ASTRA components.

One row per paper model; columns are per-component shares of total chip
energy for a full inference.  Validation: serialization machinery (fresh
serializers + replay registers + B-to-S) together with the OAG modulators
dominates, and ADC (final outputs only) stays minor.
"""
from __future__ import annotations

from repro.configs import PAPER_MODELS, PAPER_SEQ_LEN, get_arch
from repro.core.energy import AstraChipConfig
from repro.core.simulator import simulate

COMPONENTS = ("serializer", "replay", "bts", "oag_mod", "laser", "pca", "adc",
              "sram", "hbm", "nlu")


def run(log=print):
    chip = AstraChipConfig()
    log("# Fig5: per-component energy share (%) per model")
    log("energy_breakdown,model,total_mJ," + ",".join(COMPONENTS))
    out = {}
    ok = True
    for name in PAPER_MODELS:
        cfg = get_arch(name)
        rep = simulate(cfg, chip, seq=PAPER_SEQ_LEN[name])
        tot = rep.total_energy_j
        shares = {c: 100.0 * rep.energy_j.get(c, 0.0) / tot for c in COMPONENTS}
        log(f"energy_breakdown,{name},{tot * 1e3:.3f}," +
            ",".join(f"{shares[c]:.1f}" for c in COMPONENTS))
        front = shares["serializer"] + shares["replay"] + shares["bts"] + shares["oag_mod"]
        ok &= front > 40.0 and shares["adc"] < front
        out[name] = {"total_mJ": tot * 1e3, **shares}
    log(f"energy_breakdown,serializers+OAGs dominate,{'PASS' if ok else 'FAIL'}")
    return {"models": out, "claim_pass": bool(ok)}


if __name__ == "__main__":
    run()
