"""§III speedup table: ASTRA latency vs every baseline per paper model.

Claim under test: >=7.6x speedup over the best (fastest) state-of-the-art
accelerator on every model.
"""
from __future__ import annotations

from repro.configs import PAPER_MODELS, PAPER_SEQ_LEN, get_arch
from repro.core.baselines import BASELINES, simulate_baseline
from repro.core.energy import AstraChipConfig
from repro.core.simulator import simulate

ACCELS = [b for b in BASELINES if b not in ("cpu", "gpu", "tpu")]


def run(log=print):
    chip = AstraChipConfig()
    log("# speedup of ASTRA over each platform (x, higher is better)")
    log("speedup,model,astra_us," + ",".join(BASELINES))
    out = {}
    worst = float("inf")
    for model in PAPER_MODELS:
        cfg = get_arch(model)
        seq = PAPER_SEQ_LEN[model]
        astra = simulate(cfg, chip, seq=seq)
        sp = {}
        for b, spec in BASELINES.items():
            rep = simulate_baseline(spec, cfg, seq)
            sp[b] = rep.latency_s / astra.latency_s
        best_accel = min(sp[b] for b in ACCELS)
        worst = min(worst, best_accel)
        log(f"speedup,{model},{astra.latency_s * 1e6:.1f}," +
            ",".join(f"{sp[b]:.1f}" for b in BASELINES))
        out[model] = {"astra_us": astra.latency_s * 1e6, **sp}
    ok = worst >= 7.6
    log(f"speedup,min speedup vs best accelerator={worst:.2f} (>=7.6),"
        f"{'PASS' if ok else 'FAIL'}")
    return {"models": out, "min_speedup_vs_best_accel": worst, "claim_pass": bool(ok)}


if __name__ == "__main__":
    run()
