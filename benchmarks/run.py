"""Benchmark aggregator: one section per paper table/figure + the roofline.

  PYTHONPATH=src python -m benchmarks.run [--only accuracy,speedup,...]
                                          [--tune-env]

Writes machine-readable results to artifacts/bench/<name>.json alongside the
printed CSV-ish lines, plus ``BENCH_<name>.json`` files at the repo root
and a stable-schema ``BENCH_summary.json`` index (one entry per section:
headline metric, claim pass/fail, timestamp) so the perf trajectory is
tracked across PRs.

``--tune-env`` (opt-in, also ``BENCH_TUNE_ENV=1``) applies the
allocator/logging environment tuning common to JAX benchmark rigs —
tcmalloc via ``LD_PRELOAD`` when present on the system (re-execs the
process once to take effect), silenced TF logging, and no large-alloc
warnings.  Off by default: wall-clock numbers should be reproducible
with the environment the caller chose.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from benchmarks import (
    accuracy, decode_attn, energy_breakdown, energy_comparison, faults,
    kv_quant, pairing_ablation, roofline, serve_throughput, speedup, traffic,
    vdpe_scaling,
)

SECTIONS = {
    "vdpe_scaling": vdpe_scaling.run,       # Fig. 4
    "energy_breakdown": energy_breakdown.run,  # Fig. 5
    "energy_comparison": energy_comparison.run,  # Fig. 6
    "speedup": speedup.run,                 # SIII speedup claim
    "pairing_ablation": pairing_ablation.run,  # beyond-paper: decorrelation study
    "accuracy": accuracy.run,               # SIII accuracy claim (trains a model)
    "roofline": roofline.run,               # assignment SRoofline
    "roofline_compare": roofline.compare,   # SPerf: baseline vs optimized bounds
    "serve_throughput": serve_throughput.run,  # ISSUE 1: fused vs per-step decode
    "kv_cache": serve_throughput.run_kv_cache,  # ISSUE 3: shared-prefix TTFT
    "scheduler": serve_throughput.run_scheduler,  # ISSUE 4: chunked-prefill ITL
    "decode_attn": decode_attn.run,         # ISSUE 5: gather-free paged decode
    "traffic": traffic.run_smoke,           # ISSUE 7: SLO-goodput vs load
    "kv_quant": kv_quant.run,               # ISSUE 8: int8 paged KV blocks
    "faults": faults.run_smoke,             # ISSUE 10: fault isolation/recovery
}

# the one number per section worth tracking across PRs (key into the
# section's result dict; sections without a scalar headline stay null)
HEADLINES = {
    "energy_comparison": "worst_accel_ratio",
    "speedup": "min_speedup_vs_best_accel",
    "accuracy": "worst_delta_pct",
    "serve_throughput": "min_fused_speedup_b8",
    "kv_cache": "best_ttft_speedup",
    "scheduler": "itl_improvement",
    "decode_attn": "speedup",
    "traffic": "peak_goodput_rps",
    "kv_quant": "capacity_ratio",
    "faults": "unaffected_identical_frac",
}

# allocator/logging environment applied by --tune-env (SNIPPETS.md 1-2
# idiom: tcmalloc preload + quiet TF + no large-alloc reports)
_TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
)
_TUNE_ENV = {
    "TF_CPP_MIN_LOG_LEVEL": "4",
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
}


def maybe_tune_env(argv=None) -> None:
    """Apply the opt-in benchmark environment, re-execing once if a
    tcmalloc preload needs to take effect.  No-op unless ``--tune-env``
    or ``BENCH_TUNE_ENV=1`` is present, or if already applied."""
    argv = sys.argv if argv is None else argv
    want = "--tune-env" in argv or os.environ.get("BENCH_TUNE_ENV") == "1"
    if not want or os.environ.get("_BENCH_ENV_APPLIED") == "1":
        return
    os.environ.update(_TUNE_ENV)
    os.environ["_BENCH_ENV_APPLIED"] = "1"
    preload = os.environ.get("LD_PRELOAD", "")
    if "tcmalloc" not in preload:
        lib = next((p for p in _TCMALLOC_CANDIDATES if os.path.exists(p)), None)
        if lib is not None:
            os.environ["LD_PRELOAD"] = f"{preload} {lib}".strip()
            os.execv(sys.executable, [sys.executable] + argv)
    # no tcmalloc on the system (or already preloaded): the env vars
    # above still apply to this process


def _headline(name: str, result) -> dict:
    key = HEADLINES.get(name)
    value = None
    if key is not None and isinstance(result, dict):
        v = result.get(key)
        if isinstance(v, (int, float)):
            value = float(v)
    claim = result.get("claim_pass") if isinstance(result, dict) else None
    return {"headline_metric": key, "headline_value": value,
            "claim_pass": (bool(claim) if claim is not None else None)}


def main():
    maybe_tune_env()
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset")
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--tune-env", action="store_true",
                    help="apply tcmalloc/TF-logging env tuning (opt-in)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = [s for s in args.only.split(",") if s]
    # reject typo'd section names loudly — a silently-empty run used to
    # look identical to an all-sections-skipped one
    from repro.launch.flags import check_choices

    check_choices(ap, "--only", only, list(SECTIONS))
    failures = []
    ran = {}
    for name, fn in SECTIONS.items():
        if only and name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            result = fn()
            for path in (os.path.join(args.out, name + ".json"),
                         os.path.join(REPO_ROOT, f"BENCH_{name}.json")):
                with open(path, "w") as f:
                    json.dump(result, f, indent=2, default=float)
            ran[name] = result
            if isinstance(result, dict) and result.get("claim_pass") is False:
                failures.append(name)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        print(f"({name}: {time.time() - t0:.1f}s)", flush=True)
    print("\n===== summary =====")
    print("benchmarks,failures," + (";".join(failures) if failures else "none"))
    # merge into the existing index so `--only` runs don't erase the other
    # sections' entries from the cross-PR trajectory.  Schema per section
    # (stable across PRs): name, headline_metric, headline_value,
    # claim_pass (null when the section states no claim), unix_time,
    # failed.
    summary_path = os.path.join(REPO_ROOT, "BENCH_summary.json")
    sections: dict = {}
    if os.path.exists(summary_path):
        try:
            with open(summary_path) as f:
                sections = json.load(f).get("sections", {})
        except (json.JSONDecodeError, AttributeError):
            sections = {}
    # upgrade pre-schema entries in place so every section has the keys
    for name, entry in sections.items():
        sections[name] = {
            "name": name, "headline_metric": HEADLINES.get(name),
            "headline_value": None, "claim_pass": None,
            "unix_time": None, "failed": None, **entry}
    now = time.time()
    for name, result in ran.items():
        sections[name] = {"name": name, **_headline(name, result),
                          "unix_time": now, "failed": name in failures}
    for name in failures:
        sections.setdefault(name, {
            "name": name, "headline_metric": HEADLINES.get(name),
            "headline_value": None, "claim_pass": None,
            "unix_time": now, "failed": True})
    with open(summary_path, "w") as f:
        json.dump({"schema_version": 1, "sections": sections,
                   "last_failures": failures}, f, indent=2)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
