"""Benchmark aggregator: one section per paper table/figure + the roofline.

  PYTHONPATH=src python -m benchmarks.run [--only accuracy,speedup,...]

Writes machine-readable results to artifacts/bench/<name>.json alongside the
printed CSV-ish lines, plus ``BENCH_<name>.json`` files at the repo root
(and a ``BENCH_summary.json`` index) so the perf trajectory is tracked
across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from benchmarks import (
    accuracy, decode_attn, energy_breakdown, energy_comparison,
    pairing_ablation, roofline, serve_throughput, speedup, vdpe_scaling,
)

SECTIONS = {
    "vdpe_scaling": vdpe_scaling.run,       # Fig. 4
    "energy_breakdown": energy_breakdown.run,  # Fig. 5
    "energy_comparison": energy_comparison.run,  # Fig. 6
    "speedup": speedup.run,                 # SIII speedup claim
    "pairing_ablation": pairing_ablation.run,  # beyond-paper: decorrelation study
    "accuracy": accuracy.run,               # SIII accuracy claim (trains a model)
    "roofline": roofline.run,               # assignment SRoofline
    "roofline_compare": roofline.compare,   # SPerf: baseline vs optimized bounds
    "serve_throughput": serve_throughput.run,  # ISSUE 1: fused vs per-step decode
    "kv_cache": serve_throughput.run_kv_cache,  # ISSUE 3: shared-prefix TTFT
    "scheduler": serve_throughput.run_scheduler,  # ISSUE 4: chunked-prefill ITL
    "decode_attn": decode_attn.run,         # ISSUE 5: gather-free paged decode
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = [s for s in args.only.split(",") if s]
    failures = []
    ran = []
    for name, fn in SECTIONS.items():
        if only and name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            result = fn()
            for path in (os.path.join(args.out, name + ".json"),
                         os.path.join(REPO_ROOT, f"BENCH_{name}.json")):
                with open(path, "w") as f:
                    json.dump(result, f, indent=2, default=float)
            ran.append(name)
            if isinstance(result, dict) and result.get("claim_pass") is False:
                failures.append(name)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        print(f"({name}: {time.time() - t0:.1f}s)", flush=True)
    print("\n===== summary =====")
    print("benchmarks,failures," + (";".join(failures) if failures else "none"))
    # merge into the existing index so `--only` runs don't erase the other
    # sections' entries from the cross-PR trajectory
    summary_path = os.path.join(REPO_ROOT, "BENCH_summary.json")
    sections: dict = {}
    if os.path.exists(summary_path):
        try:
            with open(summary_path) as f:
                sections = json.load(f).get("sections", {})
        except (json.JSONDecodeError, AttributeError):
            sections = {}
    now = time.time()
    for name in ran:
        sections[name] = {"unix_time": now, "failed": name in failures}
    for name in failures:
        sections.setdefault(name, {"unix_time": now, "failed": True})
    with open(summary_path, "w") as f:
        json.dump({"sections": sections, "last_failures": failures}, f, indent=2)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
