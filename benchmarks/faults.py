"""Fault-isolated serving: blast radius and recovery under injected faults.

Replays one seeded traffic trace on a **virtual clock** three ways —
fault-free, under a periodic fault schedule with no retry, and under the
same schedule with capped-backoff retry — through the supervised stack
(``serve/faults.py`` + ``serve/supervisor.py`` + the front-end's
deadline/retry surface, docs/SERVING.md §Fault tolerance).  Virtual time
plus seeded injection makes every number a deterministic function of
``(trace seed, fault seed, engine config, step)``.

Claims under test (ISSUE 10 acceptance):

* **isolation** — under one fault per ``FAULT_EVERY`` supervisor steps,
  ≥99 % of unaffected requests (those not quarantined/shed) finish
  token-identical to the fault-free replay;
* **recovery** — with retries on, SLO-goodput stays within 10 % of the
  fault-free replay's goodput;
* **no leaks** — the engine audit (pool refcounts vs slot tables vs
  prefix tree vs supervisor holds) is clean after every replay.

Writes ``BENCH_faults.json`` at the repo root (and is registered as the
``faults`` section of ``benchmarks/run.py``).

  PYTHONPATH=src python benchmarks/faults.py [--smoke] [--json out.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import (
    EngineSupervisor, FrontendConfig, ServeConfig, ServeEngine,
    ServeFaultInjector, ServeFrontend,
)
from repro.traffic import (
    SLOConfig, VirtualClock, evaluate, generate_trace, replay_trace,
    trace_max_len,
)

ARCH, MODE = "stablelm-1.6b", "exact"
STEP_S = 0.05                      # virtual seconds per engine round
SLO = SLOConfig(ttft_s=1.0, itl_s=0.3)
RATE = 12.0                        # near-saturation for 4 slots
FAULT_EVERY = 100                  # headline: 1 fault per 100 steps
FAULT_EVERY_SMOKE = 20             # denser for the short CI trace
FAULT_KINDS = ("step_error", "nonfinite_logits", "pool_pressure")
SERVE_KW = dict(kv_block_size=16, prefix_cache=True)
IDENTICAL_FLOOR = 0.99             # isolation claim
GOODPUT_RATIO_FLOOR = 0.90         # recovery claim


def _model(key):
    cfg = get_arch(ARCH).reduced()
    model = Model(cfg, ModelOptions(cc=ComputeConfig(MODE)))
    params = Model(cfg, ModelOptions()).init(key)
    return cfg, model, params


def _round16(n: int) -> int:
    return -(-n // 16) * 16


def _stack(model, params, max_len, every=0, retries=0, fault_seed=0):
    clk = VirtualClock()
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=4, max_len=max_len, chunk_steps=4,
        astra_accounting=False, **SERVE_KW), clock=clk)
    injector = ServeFaultInjector()
    if every:
        # horizon comfortably past any replay length; unpopped specs are free
        injector = ServeFaultInjector.periodic(
            n_steps=100_000, every=every, kinds=FAULT_KINDS, seed=fault_seed)
    sup = EngineSupervisor(eng, injector)
    fe = ServeFrontend(eng, FrontendConfig(max_retries=retries,
                                           retry_backoff_s=0.25),
                       clock=clk, supervisor=sup)
    return fe


def _replay(model, params, trace, max_len, **kw):
    fe = _stack(model, params, max_len, **kw)
    r = replay_trace(fe, trace, virtual_step_s=STEP_S)
    audit = fe.engine.audit(external_refs=fe.supervisor.held_blocks)
    return r, fe, audit


def run(log=print, smoke=False):
    n = 16 if smoke else 64
    every = FAULT_EVERY_SMOKE if smoke else FAULT_EVERY
    if smoke:
        log(f"# smoke: n={n}, fault period {every} steps (full run: "
            f"n=64, period {FAULT_EVERY})")
    log(f"# fault isolation + recovery (virtual clock, step={STEP_S}s, "
        f"1 fault per {every} steps, kinds={','.join(FAULT_KINDS)})")
    cfg, model, params = _model(jax.random.PRNGKey(0))
    trace = generate_trace("chat", RATE, n, seed=7, vocab=cfg.vocab)
    max_len = _round16(trace_max_len(trace))

    r0, fe0, audit0 = _replay(model, params, trace, max_len)
    m0 = evaluate(r0.outputs, r0.duration_s, SLO, offered_rps=RATE)
    ref = {rid: r0.outputs_by_id[rid].tokens for rid in r0.request_ids}
    log(f"faults,baseline,completed={m0['n_completed']}/{m0['n_offered']},"
        f"goodput={m0['goodput_rps']:.2f}rps")

    # ---- faulted, no retry: measure the blast radius
    r1, fe1, audit1 = _replay(model, params, trace, max_len, every=every)
    m1 = evaluate(r1.outputs, r1.duration_s, SLO, offered_rps=RATE)
    sup_st = fe1.supervisor.stats
    eng_st = fe1.engine.stats()
    n_unaffected = n_identical = 0
    for i, rid0 in enumerate(r0.request_ids):
        o = r1.outputs_by_id[r1.request_ids[i]]
        if o.fault_reason is None and o.reject_reason is None:
            n_unaffected += 1
            if np.array_equal(o.tokens, ref[rid0]):
                n_identical += 1
    identical_frac = n_identical / max(n_unaffected, 1)
    isolation_ok = (sup_st["faults_injected"] > 0
                    and identical_frac >= IDENTICAL_FLOOR)
    log(f"faults,injected={sup_st['faults_injected']},"
        f"quarantined={eng_st['n_quarantined']},shed={eng_st['n_shed']},"
        f"unaffected={n_unaffected},identical={n_identical}"
        f"({identical_frac:.0%}),degraded={eng_st['degraded_level']}")

    # ---- faulted, with retry: measure recovery
    r2, fe2, audit2 = _replay(model, params, trace, max_len, every=every,
                              retries=2)
    m2 = evaluate(r2.outputs, r2.duration_s, SLO, offered_rps=RATE)
    goodput_ratio = m2["goodput_rps"] / max(m0["goodput_rps"], 1e-9)
    recovery_ok = goodput_ratio >= GOODPUT_RATIO_FLOOR
    log(f"faults,retry,retries={fe2.stats['retries']},"
        f"completed={m2['n_completed']}/{m2['n_offered']},"
        f"goodput={m2['goodput_rps']:.2f}rps"
        f"({goodput_ratio:.0%} of fault-free)")

    leaks_ok = all(a["leaked_blocks"] == 0 and a["leaked_bytes"] == 0
                   for a in (audit0, audit1, audit2))
    conserved = all(
        m["n_offered"] == (m["n_completed"] + m["n_rejected"]
                           + m["n_faulted"] + m["n_cancelled"]) == n
        for m in (m0, m1, m2))
    ok = isolation_ok and recovery_ok and leaks_ok and conserved
    log(f"faults,isolation={isolation_ok},recovery={recovery_ok},"
        f"no_leaks={leaks_ok},conserved={conserved},"
        f"{'PASS' if ok else 'FAIL'}")
    return {
        "arch": ARCH, "mode": MODE, "virtual_step_s": STEP_S,
        "slo": dataclasses.asdict(SLO), "n_per_trace": n,
        "rate_rps": RATE, "fault_every": every,
        "fault_kinds": list(FAULT_KINDS),
        "baseline": m0, "faulted": {**m1, **fe1.stats},
        "retry": {**m2, **fe2.stats},
        "supervisor": sup_st,
        "degraded_transitions": eng_st["degraded_transitions"],
        "n_unaffected": n_unaffected,
        "unaffected_identical_frac": identical_frac,
        "goodput_ratio_vs_fault_free": goodput_ratio,
        "isolation_ok": bool(isolation_ok),
        "recovery_ok": bool(recovery_ok),
        "no_leaks": bool(leaks_ok),
        "conserved": bool(conserved),
        "claim": f"under 1 fault per {every} steps, >=99% of unaffected "
                 "requests are token-identical to a fault-free replay; "
                 "with retries, goodput stays within 10% of fault-free; "
                 "audits find zero leaked blocks",
        "claim_pass": bool(ok),
    }


def run_smoke(log=print):
    return run(log=log, smoke=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace + denser fault period (CI)")
    ap.add_argument("--json", default="", help="extra copy of the results")
    args = ap.parse_args(argv)
    t0 = time.time()
    out = run(smoke=args.smoke)
    path = os.path.join(REPO_ROOT, "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {path} ({time.time() - t0:.1f}s)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
