"""Serving throughput: fused scan decode vs per-step dispatch,
shared-prefix time-to-first-token under the paged KV prefix cache, and
inter-token latency under the chunked-prefill scheduler.

Part 1 (``run``) sweeps batch size x prompt-length mix on a reduced
config and reports decode tok/s for:

* ``unfused`` — the seed driver's loop: one ``jit(decode)`` dispatch per
  token (host overhead per step),
* ``fused``   — the serve engine's ``lax.scan`` chunked loop: one dispatch
  per chunk (``repro.serve.decode_loop``),
* ``engine``  — the full continuous-batching engine on the same workload
  (packed prefill + chunked fused decode + accounting overheads).

Claim under test (ISSUE 1): fused >= 2x unfused at batch 8.

Part 2 (``run_kv_cache``) serves a shared-prefix workload (think: one
system prompt, many user suffixes) with the radix-tree prefix cache on
vs off (``ServeConfig(kv_block_size=..., prefix_cache=...)``).

Claim under test (ISSUE 3): prefix reuse cuts time-to-first-token >= 2x
at >= 50 % prefix overlap, token-identically.

Part 3 (``run_scheduler``) admits one long prompt into a batch of
actively decoding requests with blocking full-prompt admission vs the
chunked-prefill scheduler (``ServeConfig(prefill_chunk_tokens=...)``,
docs/SERVING.md §Scheduling) and compares the decoding slots' *max
inter-token latency* — the head-of-line-blocking stall.

Claim under test (ISSUE 4): chunked prefill improves the active slots'
max ITL >= 2x vs blocking admission, token-identically.

Always writes machine-readable results to ``BENCH_serve_throughput.json``
/ ``BENCH_kv_cache.json`` / ``BENCH_scheduler.json`` at the repo root
(the cross-PR perf trajectory); ``--json`` adds an extra copy, ``--only``
selects one part.

  PYTHONPATH=src python benchmarks/serve_throughput.py [--only scheduler]
"""
from __future__ import annotations

import argparse
import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import GREEDY, ServeConfig, ServeEngine, make_fused_decode, packed_prefill, unfused_decode
from repro.serve.sampling import sample_next_token

GEN = 32


def _setup(arch: str, mode: str, batch: int, prompt_lens, key):
    cfg = get_arch(arch).reduced()
    model = Model(cfg, ModelOptions(cc=ComputeConfig(mode)))
    params = Model(cfg, ModelOptions()).init(key)
    lens = [prompt_lens[i % len(prompt_lens)] for i in range(batch)]
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,), 0, cfg.vocab))
               for i, l in enumerate(lens)]
    return cfg, model, params, prompts, lens


def _prefill_uniform(model, params, prompts, max_len, key):
    b = len(prompts)
    s0 = prompts[0].shape[-1]
    tokens = jnp.asarray(np.stack(prompts))
    lengths = jnp.full((b,), s0, jnp.int32)
    last, states = packed_prefill(model, params, tokens, lengths, max_len,
                                  lengths_static=[s0] * b)
    tok = sample_next_token(last, GREEDY, key, model.cfg)
    return tok, states, jnp.full((b,), s0, jnp.int32)


def _time_decode(fn, warmup: bool = True):
    if warmup:
        jax.block_until_ready(fn())
    t0 = time.time()
    toks = fn()
    jax.block_until_ready(toks)
    return time.time() - t0


def bench_cell(arch: str, mode: str, batch: int, prompt_lens, chunk: int, log=print):
    key = jax.random.PRNGKey(0)
    cfg, model, params, prompts, lens = _setup(arch, mode, batch, prompt_lens, key)
    max_len = max(lens) + GEN + 1
    steps = GEN - 1

    # uniform-length variants measure the *decode loop* in isolation
    uni = [np.asarray(p)[: min(lens)] for p in prompts]
    tok, states, pos = _prefill_uniform(model, params, uni, max_len, key)

    t_unfused = _time_decode(
        lambda: unfused_decode(model, params, tok, states, pos, key, steps, GREEDY)[0]
    )
    fused = make_fused_decode(model)
    t_fused = _time_decode(
        lambda: fused(params, tok, states, pos, key, steps=steps, sampler=GREEDY)[0]
    )

    # full engine on the mixed-length stream (end-to-end, incl. prefill)
    def run_engine():
        eng = ServeEngine(model, params, ServeConfig(
            max_slots=batch, max_len=max_len, chunk_steps=chunk,
            astra_accounting=False))
        return [o.tokens for o in eng.generate_batch(prompts, GEN)]

    run_engine()  # warm the jit caches
    t0 = time.time()
    outs = run_engine()
    t_engine = time.time() - t0
    n_engine = sum(t.shape[-1] for t in outs)

    cell = {
        "arch": arch, "mode": mode, "batch": batch,
        "prompt_lens": sorted(set(lens)), "gen": GEN, "chunk_steps": chunk,
        "unfused_tok_s": batch * steps / t_unfused,
        "fused_tok_s": batch * steps / t_fused,
        "engine_tok_s": n_engine / t_engine,
        "fused_speedup": t_unfused / t_fused,
    }
    log(f"serve,{arch},{mode},b={batch},mix={'/'.join(map(str, cell['prompt_lens']))},"
        f"unfused={cell['unfused_tok_s']:.1f},fused={cell['fused_tok_s']:.1f},"
        f"engine={cell['engine_tok_s']:.1f},speedup={cell['fused_speedup']:.2f}x")
    return cell


def run(log=print):
    log("# decode tok/s: fused scan vs per-step dispatch (reduced configs)")
    cells = []
    for batch in (1, 4, 8):
        cells.append(bench_cell("stablelm-1.6b", "int8", batch, [32], chunk=8, log=log))
    cells.append(bench_cell("stablelm-1.6b", "int8", 8, [16, 32, 64], chunk=8, log=log))
    cells.append(bench_cell("stablelm-1.6b", "exact", 8, [32], chunk=8, log=log))
    cells.append(bench_cell("recurrentgemma-2b", "int8", 8, [16, 32], chunk=8, log=log))
    at8 = [c for c in cells if c["batch"] == 8 and c["arch"] == "stablelm-1.6b"
           and c["mode"] == "int8"]
    worst = min(c["fused_speedup"] for c in at8)
    ok = worst >= 2.0
    log(f"serve,min fused speedup at batch 8={worst:.2f}x (>=2.0),"
        f"{'PASS' if ok else 'FAIL'}")
    return {"cells": cells, "min_fused_speedup_b8": worst, "claim_pass": bool(ok)}


# ------------------------------------------------- shared-prefix TTFT
def _ttft_engine(model, params, prompts, prime, max_len, block, prefix_on,
                 repeats=3):
    """Best-of-N wall time for one packed admission of ``prompts`` with
    ``max_new_tokens=1`` — prefill through first sampled token (TTFT).
    Each timed run uses a fresh engine primed with ``prime`` (the shared
    prefix plus one token, so the whole prefix is interned block-aligned),
    keeping cache state identical across repeats."""

    def once():
        eng = ServeEngine(model, params, ServeConfig(
            max_slots=len(prompts), max_len=max_len, chunk_steps=4,
            kv_block_size=block, prefix_cache=prefix_on,
            astra_accounting=False))
        eng.generate_batch([prime], 1)  # prime: interns the prefix
        t0 = time.time()
        outs = eng.generate_batch(prompts, 1)
        dt = time.time() - t0
        return dt, [o.tokens for o in outs], eng.prefix_stats

    once()  # warm the jit caches for this (shapes, ctx-bucket) combo
    best, toks, stats = min((once() for _ in range(repeats)), key=lambda r: r[0])
    return best, toks, stats


def run_kv_cache(log=print):
    log("# shared-prefix TTFT: radix prefix cache on vs off (reduced config)")
    # exact mode: int8's dynamic per-tensor act scales depend on the packed
    # batch shape, so on/off token parity there needs PTQ calibration —
    # the parity claim is cleanest under exact numerics
    arch, mode, batch, block = "stablelm-1.6b", "exact", 8, 16
    key = jax.random.PRNGKey(0)
    cfg = get_arch(arch).reduced()
    model = Model(cfg, ModelOptions(cc=ComputeConfig(mode)))
    params = Model(cfg, ModelOptions()).init(key)
    prompt_len = 512
    max_len = prompt_len + 8
    cells = []
    for prefix_len in (256, 448):  # 50 % and 87.5 % prompt overlap
        prefix = np.asarray(
            jax.random.randint(jax.random.fold_in(key, prefix_len),
                               (prefix_len,), 0, cfg.vocab), np.int32)
        prime = np.concatenate([prefix, np.zeros(1, np.int32)])
        prompts = []
        for i in range(batch):
            tail = np.asarray(
                jax.random.randint(jax.random.fold_in(key, 1000 + i),
                                   (prompt_len - prefix_len,), 0, cfg.vocab), np.int32)
            prompts.append(np.concatenate([prefix, tail]))
        t_on, toks_on, stats = _ttft_engine(model, params, prompts, prime,
                                            max_len, block, prefix_on=True)
        t_off, toks_off, _ = _ttft_engine(model, params, prompts, prime,
                                          max_len, block, prefix_on=False)
        identical = all(np.array_equal(a, b) for a, b in zip(toks_on, toks_off))
        overlap = prefix_len / prompt_len
        cell = {
            "arch": arch, "mode": mode, "batch": batch,
            "prompt_len": prompt_len, "prefix_len": prefix_len,
            "overlap": overlap, "kv_block_size": block,
            "ttft_on_s": t_on, "ttft_off_s": t_off,
            "ttft_speedup": t_off / t_on,
            "hit_tokens": stats.get("hit_tokens", 0),
            "tokens_identical": bool(identical),
        }
        cells.append(cell)
        log(f"kv_cache,{arch},{mode},b={batch},overlap={overlap:.0%},"
            f"ttft_on={t_on * 1e3:.1f}ms,ttft_off={t_off * 1e3:.1f}ms,"
            f"speedup={cell['ttft_speedup']:.2f}x,identical={identical}")
    # claim (ISSUE 3 acceptance): exhibit >= 2x TTFT at an overlap >= 50 %,
    # token-identically.  Both cells are recorded; the gate is existential
    # (>= 2x somewhere at qualifying overlap), with per-cell speedups in
    # the JSON so the full overlap curve stays visible.
    qualifying = [c for c in cells if c["overlap"] >= 0.5 and c["tokens_identical"]]
    best = max((c["ttft_speedup"] for c in qualifying), default=0.0)
    ok = best >= 2.0 and all(c["tokens_identical"] for c in cells)
    log(f"kv_cache,best TTFT speedup at >=50% overlap={best:.2f}x (>=2.0),"
        f"{'PASS' if ok else 'FAIL'}")
    return {
        "cells": cells,
        "claim": ">=2x TTFT at some overlap >= 50%, token-identical",
        "best_ttft_speedup": best,
        "min_ttft_speedup": min((c["ttft_speedup"] for c in qualifying), default=0.0),
        "claim_pass": bool(ok),
    }


# ------------------------------------------- chunked-prefill scheduler
def _serve_interleaved(model, params, shorts, long_prompt, gen_short, gen_long,
                       max_len, block, chunk_tokens):
    """Serve ``shorts`` to steady-state decode, admit ``long_prompt``
    mid-stream, drain.  Returns (short outputs, long output)."""
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=len(shorts) + 1, max_len=max_len, chunk_steps=2,
        kv_block_size=block, prefix_cache=False, astra_accounting=False,
        prefill_chunk_tokens=chunk_tokens))
    short_ids = [eng.submit(p, gen_short) for p in shorts]
    outs = []
    for _ in range(3):  # shorts admitted and decoding before the long lands
        outs.extend(eng.step())
    long_id = eng.submit(long_prompt, gen_long)
    outs.extend(eng.run())
    by_id = {o.request_id: o for o in outs}
    return [by_id[i] for i in short_ids], by_id[long_id]


def run_scheduler(log=print):
    log("# mid-stream long-prompt admission: blocking vs chunked prefill "
        "(reduced config)")
    # the long prompt is sized so the blocking full-prompt prefill costs
    # well over any host-scheduling noise (~100ms+), keeping the >=2x
    # gate robust; the chunked side's dispatches stay budget-bounded
    arch, mode = "stablelm-1.6b", "exact"
    n_short, prompt_short, gen_short = 4, 16, 80
    prompt_long, gen_long = 2048, 4
    block, budget = 16, 128
    key = jax.random.PRNGKey(0)
    cfg = get_arch(arch).reduced()
    model = Model(cfg, ModelOptions(cc=ComputeConfig(mode)))
    params = Model(cfg, ModelOptions()).init(key)
    max_len = prompt_long + gen_long + 4
    shorts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                            (prompt_short,), 0, cfg.vocab), np.int32)
              for i in range(n_short)]
    long_p = np.asarray(jax.random.randint(jax.random.fold_in(key, 99),
                                           (prompt_long,), 0, cfg.vocab), np.int32)

    def once(chunk_tokens):
        so, lo = _serve_interleaved(model, params, shorts, long_p, gen_short,
                                    gen_long, max_len, block, chunk_tokens)
        max_itl = max(o.timing.max_itl_s for o in so)
        toks = [o.tokens for o in so] + [lo.tokens]
        return max_itl, lo.timing.ttft_s, toks

    results = {}
    for name, chunk in (("blocking", 0), ("chunked", budget)):
        once(chunk)  # warm the jit caches (same bucket sequence as timed runs)
        best = min((once(chunk) for _ in range(3)),
                   key=lambda r: r[0])  # best-of-3 max-ITL
        results[name] = best
        log(f"scheduler,{arch},{mode},{name},max_itl="
            f"{best[0] * 1e3:.2f}ms,long_ttft={best[1] * 1e3:.1f}ms")
    identical = all(np.array_equal(a, b) for a, b in
                    zip(results["blocking"][2], results["chunked"][2]))
    improvement = results["blocking"][0] / max(results["chunked"][0], 1e-9)
    ok = improvement >= 2.0 and identical
    log(f"scheduler,max-ITL improvement={improvement:.2f}x (>=2.0),"
        f"identical={identical},{'PASS' if ok else 'FAIL'}")
    return {
        "arch": arch, "mode": mode, "n_short": n_short,
        "prompt_short": prompt_short, "gen_short": gen_short,
        "prompt_long": prompt_long, "kv_block_size": block,
        "prefill_chunk_tokens": budget,
        "max_itl_blocking_s": results["blocking"][0],
        "max_itl_chunked_s": results["chunked"][0],
        "long_ttft_blocking_s": results["blocking"][1],
        "long_ttft_chunked_s": results["chunked"][1],
        "itl_improvement": improvement,
        "tokens_identical": bool(identical),
        "claim": ">=2x lower max inter-token latency for active slots when "
                 "a long prompt is admitted mid-decode, token-identically",
        "claim_pass": bool(ok),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", help="extra copy of the results")
    ap.add_argument("--only", default="",
                    choices=["", "fused", "kv_cache", "scheduler"],
                    help="run a single part (default: all)")
    args = ap.parse_args(argv)
    results = {}
    if args.only in ("", "fused"):
        results["serve_throughput"] = run()
    if args.only in ("", "kv_cache"):
        results["kv_cache"] = run_kv_cache()
    if args.only in ("", "scheduler"):
        results["scheduler"] = run_scheduler()
    for name, out in results.items():
        path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {path}")
    if args.json:
        # the extra copy carries every part that ran (a single-section
        # run stays shaped like that section for drop-in compatibility)
        out = next(iter(results.values())) if len(results) == 1 else results
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
