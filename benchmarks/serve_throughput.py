"""Serving throughput: fused scan decode vs the seed per-step dispatch loop.

Sweeps batch size x prompt-length mix on a reduced config and reports
decode tok/s for:

* ``unfused`` — the seed driver's loop: one ``jit(decode)`` dispatch per
  token (host overhead per step),
* ``fused``   — the serve engine's ``lax.scan`` chunked loop: one dispatch
  per chunk (``repro.serve.decode_loop``),
* ``engine``  — the full continuous-batching engine on the same workload
  (packed prefill + chunked fused decode + accounting overheads).

Claim under test (ISSUE 1): fused >= 2x unfused at batch 8.

Always writes machine-readable results to ``BENCH_serve_throughput.json``
at the repo root (the cross-PR perf trajectory); ``--json`` adds an extra
copy wherever you want it.

  PYTHONPATH=src python benchmarks/serve_throughput.py [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import GREEDY, ServeConfig, ServeEngine, make_fused_decode, packed_prefill, unfused_decode
from repro.serve.sampling import sample_next_token

GEN = 32


def _setup(arch: str, mode: str, batch: int, prompt_lens, key):
    cfg = get_arch(arch).reduced()
    model = Model(cfg, ModelOptions(cc=ComputeConfig(mode)))
    params = Model(cfg, ModelOptions()).init(key)
    lens = [prompt_lens[i % len(prompt_lens)] for i in range(batch)]
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,), 0, cfg.vocab))
               for i, l in enumerate(lens)]
    return cfg, model, params, prompts, lens


def _prefill_uniform(model, params, prompts, max_len, key):
    b = len(prompts)
    s0 = prompts[0].shape[-1]
    tokens = jnp.asarray(np.stack(prompts))
    lengths = jnp.full((b,), s0, jnp.int32)
    last, states = packed_prefill(model, params, tokens, lengths, max_len,
                                  lengths_static=[s0] * b)
    tok = sample_next_token(last, GREEDY, key, model.cfg)
    return tok, states, jnp.full((b,), s0, jnp.int32)


def _time_decode(fn, warmup: bool = True):
    if warmup:
        jax.block_until_ready(fn())
    t0 = time.time()
    toks = fn()
    jax.block_until_ready(toks)
    return time.time() - t0


def bench_cell(arch: str, mode: str, batch: int, prompt_lens, chunk: int, log=print):
    key = jax.random.PRNGKey(0)
    cfg, model, params, prompts, lens = _setup(arch, mode, batch, prompt_lens, key)
    max_len = max(lens) + GEN + 1
    steps = GEN - 1

    # uniform-length variants measure the *decode loop* in isolation
    uni = [np.asarray(p)[: min(lens)] for p in prompts]
    tok, states, pos = _prefill_uniform(model, params, uni, max_len, key)

    t_unfused = _time_decode(
        lambda: unfused_decode(model, params, tok, states, pos, key, steps, GREEDY)[0]
    )
    fused = make_fused_decode(model)
    t_fused = _time_decode(
        lambda: fused(params, tok, states, pos, key, steps=steps, sampler=GREEDY)[0]
    )

    # full engine on the mixed-length stream (end-to-end, incl. prefill)
    def run_engine():
        eng = ServeEngine(model, params, ServeConfig(
            max_slots=batch, max_len=max_len, chunk_steps=chunk,
            astra_accounting=False))
        return [o.tokens for o in eng.generate_batch(prompts, GEN)]

    run_engine()  # warm the jit caches
    t0 = time.time()
    outs = run_engine()
    t_engine = time.time() - t0
    n_engine = sum(t.shape[-1] for t in outs)

    cell = {
        "arch": arch, "mode": mode, "batch": batch,
        "prompt_lens": sorted(set(lens)), "gen": GEN, "chunk_steps": chunk,
        "unfused_tok_s": batch * steps / t_unfused,
        "fused_tok_s": batch * steps / t_fused,
        "engine_tok_s": n_engine / t_engine,
        "fused_speedup": t_unfused / t_fused,
    }
    log(f"serve,{arch},{mode},b={batch},mix={'/'.join(map(str, cell['prompt_lens']))},"
        f"unfused={cell['unfused_tok_s']:.1f},fused={cell['fused_tok_s']:.1f},"
        f"engine={cell['engine_tok_s']:.1f},speedup={cell['fused_speedup']:.2f}x")
    return cell


def run(log=print):
    log("# decode tok/s: fused scan vs per-step dispatch (reduced configs)")
    cells = []
    for batch in (1, 4, 8):
        cells.append(bench_cell("stablelm-1.6b", "int8", batch, [32], chunk=8, log=log))
    cells.append(bench_cell("stablelm-1.6b", "int8", 8, [16, 32, 64], chunk=8, log=log))
    cells.append(bench_cell("stablelm-1.6b", "exact", 8, [32], chunk=8, log=log))
    cells.append(bench_cell("recurrentgemma-2b", "int8", 8, [16, 32], chunk=8, log=log))
    at8 = [c for c in cells if c["batch"] == 8 and c["arch"] == "stablelm-1.6b"
           and c["mode"] == "int8"]
    worst = min(c["fused_speedup"] for c in at8)
    ok = worst >= 2.0
    log(f"serve,min fused speedup at batch 8={worst:.2f}x (>=2.0),"
        f"{'PASS' if ok else 'FAIL'}")
    return {"cells": cells, "min_fused_speedup_b8": worst, "claim_pass": bool(ok)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", help="extra copy of the results")
    args = ap.parse_args(argv)
    out = run()
    paths = [os.path.join(REPO_ROOT, "BENCH_serve_throughput.json")]
    if args.json:
        paths.append(args.json)
    for path in paths:
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
