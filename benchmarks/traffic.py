"""Continuous-traffic serving: SLO-goodput vs offered load (ISSUE 7).

Drives the open-loop front-end (``repro.serve.frontend``) with seeded
traffic traces (``repro.traffic``) on a **virtual clock** — each engine
round costs a fixed ``STEP_S`` of virtual time — and sweeps offered load
over scenario suites, recording per load point the full SLO scorecard:
p50/p95/p99 TTFT and ITL, rejection rate, and SLO-goodput.  Virtual time
makes every number a deterministic function of (trace, engine config,
step), so the curve is comparable across PRs; absolute wall-clock
latency lives in ``benchmarks/serve_throughput.py``.

Claims under test (ISSUE 7 acceptance):

* **determinism** — regenerating a trace is bit-identical, and replaying
  it twice through fresh engine + front-end stacks produces identical
  per-request token streams and identical SLO metrics;
* **streaming parity** — every completed request's concatenation of
  streamed chunks equals its terminal ``RequestOutput.tokens``; rejected
  requests stream nothing;
* **conservation** — every offered request terminates exactly once:
  ``n_offered == n_completed + n_rejected`` at every load point;
* **bounded backpressure** — an over-capacity burst against a tight
  admission queue keeps the waiting line's high-water mark within the
  configured bound and sheds the excess as *accounted* queue-full /
  queue-timeout rejections.

Writes ``BENCH_traffic.json`` at the repo root (and is registered as the
``traffic`` section of ``benchmarks/run.py``).

  PYTHONPATH=src python benchmarks/traffic.py [--smoke] [--json out.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import FrontendConfig, ServeConfig, ServeEngine, ServeFrontend
from repro.traffic import (
    SLOConfig, VirtualClock, evaluate, generate_trace, replay_trace,
    trace_max_len,
)

ARCH, MODE = "stablelm-1.6b", "exact"
STEP_S = 0.05  # virtual seconds per engine round
SLO = SLOConfig(ttft_s=0.5, itl_s=0.2)  # 10 rounds to first token, 4 between
# offered loads (requests/s).  With 4 slots, chunk_steps=4 and chat-suite
# generation lengths the stack saturates in the teens, so the sweep
# crosses from underload through saturation into overload.
LOADS = (4.0, 12.0, 36.0)
SUITES = ("chat", "mixed")
# paged KV + radix prefix cache for the suite with shared-prefix fan-out
SUITE_SERVE_KW = {
    "chat": dict(kv_block_size=0),
    "mixed": dict(kv_block_size=16, prefix_cache=True),
}
FRONTEND = FrontendConfig(max_queue_depth=16, queue_timeout_s=2.0)


def _stack(model, params, max_len, serve_kw, frontend_cfg=FRONTEND,
           max_slots=4):
    clk = VirtualClock()
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=max_slots, max_len=max_len, chunk_steps=4,
        astra_accounting=False, **serve_kw), clock=clk)
    return ServeFrontend(eng, frontend_cfg, clock=clk)


def _model(key):
    cfg = get_arch(ARCH).reduced()
    model = Model(cfg, ModelOptions(cc=ComputeConfig(MODE)))
    params = Model(cfg, ModelOptions()).init(key)
    return cfg, model, params


def _round16(n: int) -> int:
    return -(-n // 16) * 16


def _streams_match(result) -> bool:
    by_id = result.outputs_by_id
    for rid in result.request_ids:
        out = by_id[rid]
        if out.reject_reason is not None:
            if result.token_streams[rid].shape[-1] != 0:
                return False
        elif not np.array_equal(result.token_streams[rid], out.tokens):
            return False
    return True


def _same_replay(r1, r2) -> bool:
    if r1.request_ids != r2.request_ids:
        return False
    o1, o2 = r1.outputs_by_id, r2.outputs_by_id
    return all(
        o1[rid].reject_reason == o2[rid].reject_reason
        and np.array_equal(o1[rid].tokens, o2[rid].tokens)
        and np.array_equal(r1.token_streams[rid], r2.token_streams[rid])
        for rid in r1.request_ids)


def _traces_equal(t1, t2) -> bool:
    return len(t1) == len(t2) and all(
        a.arrival_s == b.arrival_s and a.max_new_tokens == b.max_new_tokens
        and a.scenario == b.scenario and np.array_equal(a.prompt, b.prompt)
        for a, b in zip(t1.requests, t2.requests))


def run(log=print, smoke=False):
    n = 12 if smoke else 48
    log(f"# SLO-goodput vs offered load (virtual clock, step={STEP_S}s, "
        f"n={n}/trace)")
    cfg, model, params = _model(jax.random.PRNGKey(0))
    points = []
    deterministic = parity = conserved = True
    for suite in SUITES:
        serve_kw = SUITE_SERVE_KW[suite]
        for rate in LOADS:
            trace = generate_trace(suite, rate, n, seed=7, vocab=cfg.vocab)
            if not _traces_equal(trace, generate_trace(
                    suite, rate, n, seed=7, vocab=cfg.vocab)):
                deterministic = False
            max_len = _round16(trace_max_len(trace))
            r1 = replay_trace(_stack(model, params, max_len, serve_kw),
                              trace, virtual_step_s=STEP_S)
            r2 = replay_trace(_stack(model, params, max_len, serve_kw),
                              trace, virtual_step_s=STEP_S)
            m = evaluate(r1.outputs, r1.duration_s, SLO, offered_rps=rate)
            m2 = evaluate(r2.outputs, r2.duration_s, SLO, offered_rps=rate)
            deterministic = deterministic and _same_replay(r1, r2) and m == m2
            parity = parity and _streams_match(r1)
            conserved = conserved and (
                m["n_offered"] == m["n_completed"] + m["n_rejected"] == n)
            points.append({"suite": suite, "rate_rps": rate,
                           "arrival": "poisson", **m, **r1.stats})
            log(f"traffic,{suite},rate={rate:.0f}rps,"
                f"goodput={m['goodput_rps']:.2f}rps,"
                f"ttft_p95={m['ttft_p95_s'] * 1e3:.0f}ms,"
                f"itl_p95={m['itl_p95_s'] * 1e3:.0f}ms,"
                f"rej={m['rejection_rate']:.0%},"
                f"slo_met={m['slo_attainment']:.0%}")

    # over-capacity burst against a tight queue: backpressure must be
    # bounded and the shed load accounted
    burst_cap = 4
    bt = generate_trace("chat", 60.0, max(2 * n, 24), seed=3, vocab=cfg.vocab,
                        arrival="bursty", burst_size=12)
    fe = _stack(model, params, _round16(trace_max_len(bt)),
                SUITE_SERVE_KW["chat"],
                FrontendConfig(max_queue_depth=burst_cap, queue_timeout_s=0.5),
                max_slots=2)
    rb = replay_trace(fe, bt, virtual_step_s=STEP_S)
    bm = evaluate(rb.outputs, rb.duration_s, SLO, offered_rps=60.0)
    n_rej = (rb.stats["rejected_queue_full"]
             + rb.stats["rejected_queue_timeout"])
    burst_ok = (rb.stats["max_queue_depth"] <= burst_cap
                and n_rej > 0 and n_rej == bm["n_rejected"]
                and bm["n_offered"] == bm["n_completed"] + bm["n_rejected"])
    parity = parity and _streams_match(rb)
    burst = {"suite": "chat", "rate_rps": 60.0, "arrival": "bursty",
             "max_queue_depth_cap": burst_cap, **bm, **rb.stats}
    log(f"traffic,burst,rate=60rps,queue_hw={rb.stats['max_queue_depth']}"
        f"(<= {burst_cap}),rejected={n_rej},"
        f"goodput={bm['goodput_rps']:.2f}rps,bounded={burst_ok}")

    coverage = (len({p['suite'] for p in points}) >= 2
                and len({p['rate_rps'] for p in points}) >= 3)
    ok = deterministic and parity and conserved and burst_ok and coverage
    log(f"traffic,deterministic={deterministic},stream_parity={parity},"
        f"conserved={conserved},burst_bounded={burst_ok},"
        f"{'PASS' if ok else 'FAIL'}")
    return {
        "arch": ARCH, "mode": MODE, "virtual_step_s": STEP_S,
        "slo": dataclasses.asdict(SLO), "n_per_trace": n,
        "frontend": dataclasses.asdict(FRONTEND),
        "points": points, "burst": burst,
        "peak_goodput_rps": max(p["goodput_rps"] for p in points),
        "deterministic": bool(deterministic),
        "stream_parity": bool(parity),
        "conserved": bool(conserved),
        "burst_bounded": bool(burst_ok),
        "claim": "deterministic traces+replays; streamed tokens == batch "
                 "tokens; every request terminates exactly once; bursts "
                 "shed load within the queue bound, visibly",
        "claim_pass": bool(ok),
    }


def run_smoke(log=print):
    return run(log=log, smoke=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small traces (CI): same loads/suites, fewer requests")
    ap.add_argument("--json", default="", help="extra copy of the results")
    args = ap.parse_args(argv)
    t0 = time.time()
    out = run(smoke=args.smoke)
    path = os.path.join(REPO_ROOT, "BENCH_traffic.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {path} ({time.time() - t0:.1f}s)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
