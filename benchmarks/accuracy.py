"""Paper §III accuracy reproduction: 8-bit + 128-bit streams vs FP32.

Protocol (the paper fine-tunes/evaluates real checkpoints; we train a small
transformer on the synthetic Markov LM task to a non-trivial accuracy, then
evaluate held-out next-token top-1 accuracy under every ASTRA numeric mode):

  exact          — FP32 reference
  int8           — ASTRA expectation (deployable path)
  sc             — bit-true 128-bit streams, deterministic pairing (ours)
  sc-lfsr        — bit-true, LFSR pairing (paper-faithful classic SC)
  sc-noisy       — VDPE shot-noise + 8-bit output ADC on top of streams

Claim under test: accuracy within 1.2% of FP32.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch.train import build_train_step
from repro.models.model import Model
from repro.models.transformer import ModelOptions, forward
from repro.optim import AdamWConfig, adamw_init

MODES = {
    "exact": ComputeConfig("exact"),
    "int8": ComputeConfig("int8"),
    "sc": ComputeConfig("sc"),  # thermometer x bresenham (deterministic)
    "sc-lfsr": ComputeConfig("sc", x_gen="lfsr", w_gen="bresenham"),
}


def _train_small(steps=180, seed=0):
    cfg = dataclasses.replace(
        get_arch("qwen1.5-0.5b").reduced(n_layers=2, d_model=128, head_dim=32),
        dtype="float32",
    )
    model = Model(cfg, ModelOptions())
    # low-entropy Markov + copy-span task: a trained model reaches ~30-45%
    # top-1 (vs 0.4% chance), so PTQ deltas are measured on real skill
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=seed,
                      menu_size=4, greedy_p=0.95, copy_len=16, copy_period=64)
    ds = SyntheticLMDataset(dcfg)
    step_fn = jax.jit(build_train_step(model, AdamWConfig(lr=3e-3), steps, warmup=10))
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    for s in range(steps):
        params, opt, m = step_fn(params, opt, {"tokens": jnp.asarray(ds.batch_at(s)["tokens"])})
    return cfg, params, ds, float(m["loss"])


def _top1_acc(cfg, params, ds, cc, eval_steps=(1000, 1001, 1002)):
    opts = ModelOptions(cc=cc)
    hits = total = 0
    for s in eval_steps:
        toks = jnp.asarray(ds.batch_at(s)["tokens"])
        logits, _, _ = forward(params, toks, cfg, opts)
        pred = np.asarray(jnp.argmax(logits[:, :-1], axis=-1))
        want = np.asarray(toks[:, 1:])
        hits += (pred == want).sum()
        total += want.size
    return hits / total


def run(log=print):
    t0 = time.time()
    cfg, params, ds, final_loss = _train_small()
    log(f"# accuracy: trained {cfg.name} to loss {final_loss:.3f} "
        f"({time.time() - t0:.0f}s)")
    results = {}
    ref = None
    for name, cc in MODES.items():
        acc = _top1_acc(cfg, params, ds, cc)
        if name == "exact":
            ref = acc
        results[name] = {"top1": acc, "delta_pct": 100 * (ref - acc)}
        log(f"accuracy,{name},top1={acc * 100:.2f}%,delta={100 * (ref - acc):+.2f}pp")
    worst = max(r["delta_pct"] for r in results.values())
    ok = worst <= 1.2
    log(f"accuracy,CLAIM<=1.2%,worst_delta={worst:.2f}pp,{'PASS' if ok else 'FAIL'}")
    return {"results": results, "worst_delta_pct": worst, "claim_pass": ok}


if __name__ == "__main__":
    run()
