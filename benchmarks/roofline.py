"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run.

Reads artifacts/dryrun*/ JSON records (produced by repro.launch.dryrun) and
derives, per cell:

  compute_s    = HLO_FLOPs_per_device / 197 TFLOP/s      (bf16 peak, v5e)
  memory_s     = HLO_bytes_per_device / 819 GB/s          (HBM)
  collective_s = link_traffic_bytes_per_device / 50 GB/s  (ICI, 1 link)

HLO_FLOPs/bytes are trip-weighted dot counts parsed from the optimized SPMD
HLO (XLA's cost_analysis does not unroll while loops — see launch/dryrun).
The HLO is the per-device partitioned module, so terms are per-chip already.
Also reported: MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with N =
active params and D = tokens, the useful-compute ratio, the dominant term,
and a one-line "what would move it" hint.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9
TPU_ICI_BW = 50e9

SHAPE_TOKENS = {  # (seq, batch); decode steps process batch*1 tokens
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

HINTS = {
    "compute": "raise per-chip utilization: larger per-device tiles (less padding), "
               "fewer remat recomputations, MXU-aligned (128) GEMM dims",
    "memory": "cut HBM traffic: fuse dequant/norm chains, int8 weights on the "
              "serving path, better activation-checkpoint policy",
    "collective": "re-shard the dominant all-gather/all-reduce: move FSDP gathers "
                  "off the critical path, overlap with compute, int8-compress "
                  "cross-pod reductions, flash-decoding style seq-sharded KV",
}


def terms_for(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = {"16x16": 256, "2x16x16": 512}[rec["mesh"]]
    seq, batch, kind = SHAPE_TOKENS[rec["shape"]]
    flops_dev = rec.get("hlo_flops", 0.0)
    bytes_dev = rec.get("hlo_bytes", 0.0) + rec.get("memory", {}).get("argument_size_in_bytes", 0)
    coll_dev = sum(c.get("traffic_bytes", 0.0) for c in rec.get("collectives", {}).values())
    compute_s = flops_dev / TPU_PEAK_FLOPS
    memory_s = bytes_dev / TPU_HBM_BW
    collective_s = coll_dev / TPU_ICI_BW
    tokens = batch * seq if kind in ("train", "prefill") else batch
    n = rec.get("active_params", rec.get("params", 0))
    model_flops = (6 if kind == "train" else 2) * n * tokens
    model_flops_dev = model_flops / chips
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    bound = max(compute_s, memory_s, collective_s)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops_dev / flops_dev if flops_dev else 0.0,
        # fraction of the bound the *useful* model compute represents: the
        # roofline score (1.0 = useful work saturates the binding resource)
        "roofline_frac": (model_flops_dev / TPU_PEAK_FLOPS) / bound if bound else 0.0,
        "step_time_lb_s": bound,
        "hint": HINTS[dominant],
    }


# newest-first: dryrun4 = optimized defaults (--strategy auto), dryrun3 =
# optimized code w/ baseline sharding, dryrun2 = paper-faithful baseline
DEFAULT_DIRS = ("artifacts/dryrun4", "artifacts/dryrun3", "artifacts/dryrun2", "artifacts/dryrun")


def load(out_dirs=DEFAULT_DIRS) -> List[Dict]:
    recs = {}
    for d in out_dirs:
        for path in sorted(glob.glob(os.path.join(d, "*.json"))):
            rec = json.load(open(path))
            key = (rec["arch"], rec["shape"], rec["mesh"])
            if key not in recs:  # first dir wins (newest artifacts first)
                recs[key] = rec
    return list(recs.values())


def markdown_table(out_dirs=DEFAULT_DIRS, mesh="16x16") -> str:
    """EXPERIMENTS.md-ready roofline table for one mesh."""
    rows = [t for r in load(out_dirs) if (t := terms_for(r)) and t["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        f"| arch | shape | compute_s | memory_s | collective_s | dominant | useful | frac | what moves it |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | {r['memory_s']:.3g} "
            f"| {r['collective_s']:.3g} | {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_frac']:.3f} | {r['hint'].split(':')[0]} |"
        )
    return "\n".join(lines)


def compare(log=print, baseline_dirs=("artifacts/dryrun2",), opt_dirs=("artifacts/dryrun4",),
            mesh="16x16"):
    """Baseline vs optimized step-time bounds per cell (EXPERIMENTS SPerf)."""
    import math

    def tab(dirs):
        return {(t["arch"], t["shape"]): t for r in load(dirs)
                if (t := terms_for(r)) and t["mesh"] == mesh}

    base, opt = tab(baseline_dirs), tab(opt_dirs)
    log("roofline_compare,arch,shape,bound_base_s,bound_opt_s,speedup,frac_base,frac_opt")
    gains = []
    for k in sorted(base):
        if k not in opt:
            continue
        b, o = base[k], opt[k]
        sp = b["step_time_lb_s"] / o["step_time_lb_s"] if o["step_time_lb_s"] else 0.0
        gains.append(sp)
        log(f"roofline_compare,{k[0]},{k[1]},{b['step_time_lb_s']:.3g},"
            f"{o['step_time_lb_s']:.3g},{sp:.2f},{b['roofline_frac']:.3f},{o['roofline_frac']:.3f}")
    if gains:
        geo = math.exp(sum(math.log(g) for g in gains) / len(gains))
        log(f"roofline_compare,geomean_speedup,{geo:.2f}")
        return {"geomean_speedup": geo, "n_cells": len(gains),
                "claim_pass": bool(min(gains) > 0.95)}
    return {"geomean_speedup": 0.0, "n_cells": 0, "claim_pass": False}


def run(log=print, out_dirs=DEFAULT_DIRS):
    rows = []
    skipped = []
    for rec in load(out_dirs):
        t = terms_for(rec)
        if t is None:
            skipped.append((rec["arch"], rec["shape"], rec["mesh"],
                            rec.get("reason", rec.get("error", ""))[:60]))
            continue
        rows.append(t)
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    log("# roofline terms per (arch x shape x mesh); seconds per step")
    log("roofline,arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
        "useful_ratio,roofline_frac")
    for r in rows:
        log(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
            f"{r['compute_s']:.3e},{r['memory_s']:.3e},{r['collective_s']:.3e},"
            f"{r['dominant']},{r['useful_ratio']:.3f},{r['roofline_frac']:.3f}")
    for s in skipped:
        log(f"roofline_skipped,{s[0]},{s[1]},{s[2]},{s[3]}")
    if rows:
        worst = min((r for r in rows if r["mesh"] == "16x16"),
                    key=lambda r: r["roofline_frac"], default=None)
        most_coll = max((r for r in rows if r["mesh"] == "16x16"),
                        key=lambda r: r["collective_s"], default=None)
        if worst:
            log(f"roofline,worst_cell={worst['arch']}/{worst['shape']},"
                f"frac={worst['roofline_frac']:.3f}")
        if most_coll:
            log(f"roofline,most_collective_bound={most_coll['arch']}/"
                f"{most_coll['shape']},coll_s={most_coll['collective_s']:.3e}")
    return {"rows": rows, "skipped": skipped}


if __name__ == "__main__":
    run()
