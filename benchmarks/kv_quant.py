"""Quantized paged KV cache: int8 blocks vs fp16 blocks.

The paged pool's per-position footprint sets both how many requests fit
resident (capacity) and how many bytes every decode step streams through
the block table (decode attention is bandwidth-bound — the roofline
convention of ``benchmarks/decode_attn.py``).  ``kv_quant="int8"`` stores
pool blocks as int8 against the plan's calibrated per-KV-head scales and
dequantizes per streamed block *inside* the attention kernel, so no
dense dequantized view ever exists.

Claims under test (ISSUE 8):

* **capacity** — >=1.9x more resident blocks per pool byte than fp16
  blocks (int8 halves the per-position payload: 2.0x modeled);
* **traffic** — >=1.9x lower modeled decode-step KV HBM traffic than
  fp16 blocks at equal residency (same 2x, scales are per-pool
  constants);
* **drift** — max logit/output drift vs the fp cache stays under the
  documented bounds below (calibrated static scales: round-to-nearest
  error <= scale/2 per element, no clipping at the calibration scale);
* **identity** — prefix-hit replays on a quantized pool are
  token-identical (interned int8 payloads are reused verbatim).

The capacity/traffic ratios are *modeled* against fp16 blocks (the
deployment-target fp layout): this host's fp pools are float32, so the
measured int8 ``bytes_per_block`` is compared against the same block's
element count at 2 bytes/element.  Both the measured int8 figure and
the host fp32 figure are recorded for transparency.

Drift bounds (empirical on the reduced stablelm stack, asserted here
and in ``tests/test_kv_quant.py``):

* kernel-level decode output drift (same KV content, int8 pool vs fp32
  pool, calibrated per-head scales): < ``KERNEL_DRIFT_BOUND``;
* model-level first-decode-step logit drift (quant engine vs fp engine
  from identical prompts): < ``LOGIT_DRIFT_BOUND``.

Writes ``BENCH_kv_quant.json`` at the repo root.

  PYTHONPATH=src python benchmarks/kv_quant.py [--smoke]
  PYTHONPATH=src python -m benchmarks.run --only kv_quant
"""
from __future__ import annotations

import argparse
import json
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.kernels.paged_attention import paged_attention_decode
from repro.models.attention import kv_dequantize, kv_quantize
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.core.plan import MAG_MAX
from repro.serve import ServeConfig, ServeEngine, pack_prompts

# documented drift bounds (see module docstring); test_kv_quant.py
# asserts the same constants so the benchmark and the parity matrix
# cannot drift apart
KERNEL_DRIFT_BOUND = 0.05
LOGIT_DRIFT_BOUND = 0.5

FP16_BYTES = 2
INT8_BYTES = 1


def _calibrated(cfg, key, lens):
    model = Model(cfg, ModelOptions(plan="int8"))
    params = model.init(key)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (l,), dtype=np.int32) for l in lens]
    cal_tokens, _ = pack_prompts(prompts, cfg)
    return model.calibrate(params, {"tokens": cal_tokens}), params, prompts


def _engine(model, params, prompts, gen, kv_quant=None, block=8):
    max_len = max(p.shape[-1] for p in prompts) + gen + 1
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=len(prompts), max_len=max_len, chunk_steps=4,
        kv_block_size=block, kv_quant=kv_quant, astra_accounting=False))
    return eng, eng.generate_batch(prompts, gen)


def capacity_and_traffic(model, params, prompts, gen, log=print):
    """Measured int8 vs measured host-fp vs modeled-fp16 byte accounting."""
    eng_fp, _ = _engine(model, params, prompts, gen)
    eng_q, _ = _engine(model, params, prompts, gen, kv_quant="int8")
    fp = eng_fp.kv_stats
    q = eng_q.kv_stats
    # both layouts hold the same element count per block; the int8 pool
    # measures it exactly (1 byte/element), and the fp16 deployment
    # baseline is modeled from it.  The host fp pool (model dtype —
    # bf16 here) is recorded for transparency.
    int8_bytes = q["bytes_per_block"]
    elems = int8_bytes // INT8_BYTES
    fp16_bytes = elems * FP16_BYTES
    host_fp_bytes = fp["bytes_per_block"]
    capacity_ratio = fp16_bytes / int8_bytes  # resident blocks per byte
    # decode-step streamed KV traffic at equal residency: the kernel
    # reads each live block once, so bytes scale with the element size
    live = q["live_blocks"] if q["live_blocks"] else q["pool_blocks"] - 1
    traffic_fp16 = live * fp16_bytes
    traffic_int8 = live * int8_bytes
    traffic_ratio = traffic_fp16 / traffic_int8
    log(f"kv_quant,capacity={capacity_ratio:.2f}x blocks/byte vs fp16,"
        f"traffic={traffic_ratio:.2f}x lower streamed bytes/step")
    return {
        "host_fp_bytes_per_block": host_fp_bytes,
        "modeled_fp16_bytes_per_block": fp16_bytes,
        "int8_bytes_per_block": int8_bytes,
        "capacity_ratio_vs_fp16": capacity_ratio,
        "modeled_step_bytes_fp16": traffic_fp16,
        "modeled_step_bytes_int8": traffic_int8,
        "traffic_ratio_vs_fp16": traffic_ratio,
        "pool_blocks": q["pool_blocks"],
        "pool_bytes_int8": q["pool_bytes"],
        "pool_bytes_host_fp32": fp["pool_bytes"],
    }


def kernel_drift(smoke, log=print):
    """Same KV content through an fp32 pool and an int8 pool (calibrated
    per-head scales): decode outputs must agree within the bound."""
    b, kvh, g, hd, bs, w = (2, 2, 2, 16, 8, 4) if smoke else (4, 2, 2, 32, 16, 8)
    key = jax.random.PRNGKey(7)
    kk, kv, kq = jax.random.split(key, 3)
    n_blocks = 1 + b * w
    pool_k = jax.random.normal(kk, (n_blocks, kvh, bs, hd), jnp.float32)
    pool_v = jax.random.normal(kv, (n_blocks, kvh, bs, hd), jnp.float32)
    q = jax.random.normal(kq, (b, kvh * g, hd), jnp.float32)
    table = np.zeros((b, w), np.int32)
    ids = np.arange(1, n_blocks)
    for i in range(b):
        table[i] = ids[i * w:(i + 1) * w]
    table = jnp.asarray(table)
    kv_len = jnp.full((b,), w * bs - 3, jnp.int32)
    # calibration-style scales: per-head absmax / 127 (no clipping)
    ks = jnp.max(jnp.abs(pool_k), axis=(0, 2, 3)) / MAG_MAX
    vs = jnp.max(jnp.abs(pool_v), axis=(0, 2, 3)) / MAG_MAX
    # kv_quantize aligns the scale with axis -3 (the kv-head axis of
    # [n_blocks, kvh, bs, hd] pools)
    pool_k8 = kv_quantize(pool_k, ks[None])
    pool_v8 = kv_quantize(pool_v, vs[None])
    out_fp = paged_attention_decode(q, pool_k, pool_v, table, kv_len)
    out_q = paged_attention_decode(q, pool_k8, pool_v8, table, kv_len, ks, vs)
    drift = float(jnp.max(jnp.abs(out_fp - out_q)))
    # round-trip error is bounded by scale/2 per element by construction
    rt = float(jnp.max(jnp.abs(kv_dequantize(pool_k8, ks[None]) - pool_k)))
    half_scale = float(jnp.max(ks)) / 2
    log(f"kv_quant,kernel decode drift={drift:.4f} (<{KERNEL_DRIFT_BOUND}),"
        f"roundtrip={rt:.5f} (<=scale/2={half_scale:.5f})")
    return {
        "kernel_decode_max_drift": drift,
        "kernel_drift_bound": KERNEL_DRIFT_BOUND,
        "roundtrip_max_err": rt,
        "roundtrip_bound_half_scale": half_scale,
        "ok": bool(drift < KERNEL_DRIFT_BOUND and rt <= half_scale + 1e-9),
    }


def model_logit_drift(model, params, prompts, block, log=print):
    """Max |last-position logits fp-pool vs int8-pool| over identical
    token paths — every difference is KV storage error, measured before
    any trajectory can diverge."""
    import dataclasses

    from repro.serve.prefill import prefill_paged_suffix

    model_q = dataclasses.replace(
        model, opts=dataclasses.replace(model.opts, kv_quant="int8"))
    max_len = max(p.shape[-1] for p in prompts) + 1
    w = -(-max_len // block)
    n_blocks = 1 + w
    max_d = 0.0
    for p in prompts:
        toks = jnp.asarray(p)[None]
        lens = jnp.asarray([p.shape[-1]], jnp.int32)
        row = jnp.arange(1, w + 1, dtype=jnp.int32)[None]
        start = jnp.zeros((1,), jnp.int32)
        outs = []
        for m in (model, model_q):
            states = m.init_decode_state(1, w * block, paged=(n_blocks, block))
            logits, _ = prefill_paged_suffix(m, params, toks, lens, states,
                                             row, start, w)
            outs.append(logits)
        max_d = max(max_d, float(jnp.max(jnp.abs(outs[0] - outs[1]))))
    return max_d


def model_drift_and_identity(model, params, prompts, gen, block=8, log=print):
    """First-decode-step logit drift quant vs fp, and token identity of
    prefix-hit replays on the quantized pool."""
    drift = model_logit_drift(model, params, prompts, block, log=log)
    # identity: replay the same prompts through the quant engine; the
    # second pass hits the interned int8 blocks and must reproduce the
    # first pass token for token
    eng_q, o1 = _engine(model, params, prompts, gen, kv_quant="int8",
                        block=block)
    o2 = eng_q.generate_batch(prompts, gen)
    hits = eng_q.prefix_stats["hits"]
    ident = all(np.array_equal(a.tokens, b.tokens) for a, b in zip(o1, o2))
    log(f"kv_quant,logit drift={drift:.4f} (<{LOGIT_DRIFT_BOUND}),"
        f"prefix-hit replay identical={ident} (hits={hits})")
    return {
        "first_step_logit_max_drift": drift,
        "logit_drift_bound": LOGIT_DRIFT_BOUND,
        "n_prompts": len(prompts),
        "prefix_hit_replay_identical": bool(ident),
        "prefix_hits": int(hits),
        "ok": bool(drift < LOGIT_DRIFT_BOUND and ident and hits > 0),
    }


def run(log=print, smoke=False):
    log("# quantized paged KV: int8 blocks (calibrated scales) vs fp16 blocks")
    cfg = get_arch("stablelm-1.6b").reduced()
    lens = (6, 10) if smoke else (9, 14, 21)
    gen = 4 if smoke else 8
    model, params, prompts = _calibrated(cfg, jax.random.PRNGKey(0), lens)
    bytes_ = capacity_and_traffic(model, params, prompts, gen, log=log)
    kern = kernel_drift(smoke, log=log)
    ident = model_drift_and_identity(model, params, prompts, gen, log=log)
    log(f"kv_quant,max logit drift={ident['first_step_logit_max_drift']:.4f}"
        f" (bound {LOGIT_DRIFT_BOUND})")
    ok = (bytes_["capacity_ratio_vs_fp16"] >= 1.9
          and bytes_["traffic_ratio_vs_fp16"] >= 1.9
          and kern["ok"] and ident["ok"])
    log(f"kv_quant,capacity>=1.9x and traffic>=1.9x and drift bounded and "
        f"replay identical,{'PASS' if ok else 'FAIL'}")
    return {
        "claim": ">=1.9x more resident blocks per pool byte AND >=1.9x "
                 "lower modeled decode KV traffic vs fp16 blocks; max "
                 "logit drift vs fp cache under documented bounds; "
                 "prefix-hit replays token-identical on the int8 pool",
        "smoke": bool(smoke),
        "bytes": bytes_,
        "kernel": kern,
        "identity": ident,
        "capacity_ratio": bytes_["capacity_ratio_vs_fp16"],
        "traffic_ratio": bytes_["traffic_ratio_vs_fp16"],
        "max_logit_drift": ident["first_step_logit_max_drift"],
        "claim_pass": bool(ok),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes for CI (same claims)")
    ap.add_argument("--json", default="", help="extra copy of the results")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    path = os.path.join(REPO_ROOT, "BENCH_kv_quant.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
