"""Decode-attention step cost: gathered view vs streamed KV blocks.

The serve engine's per-token hot path is attention over the paged KV
pool.  The baseline (``attn_impl="naive"``) materializes each slot's
logical cache every step — ``_paged_view`` gathers ``pool[table]`` into a
dense ``[B, W, n_kv, bs, hd]`` copy, then ``_sdpa`` runs over the whole
``max_len`` extent.  The streamed path (``attn_impl="flash"``,
``kernels/paged_attention``) walks the block table and reads K/V blocks
directly from the pool, so no logical copy ever exists and dead table
extent is neither copied nor computed.

Claim under test (ISSUE 5): **>=2x lower decode-attention step cost at
>=8 resident blocks per slot, token-identical outputs.**

The step-cost claim is scored on modeled per-step KV HBM traffic at the
deployment target (the ASTRA/TPU roofline convention of
``benchmarks/roofline.py`` — decode attention is bandwidth-bound, so
bytes moved is the step cost):

* baseline — the gather reads the full table extent from the pool,
  writes the logical copy, and ``_sdpa`` reads it back:
  ``3 * W * bs`` positions of K+V per slot, independent of fill;
* streamed — live blocks are read once, straight from the pool:
  ``ceil(kv_len / bs) * bs`` positions of K+V per slot (the index map
  clamps dead extent to the last live block, which Pallas does not
  re-copy).

Both implementations also run end to end on this host for the
correctness half of the claim: kernel-vs-oracle parity
(``interpret=True``) and engine-level token identity under an exact plan
and a PTQ-calibrated int8 plan.  Measured CPU wall times are recorded
for transparency, but interpret-mode Pallas is a correctness vehicle on
CPU, not a performance target — the JSON keeps the two numbers clearly
apart.

Writes ``BENCH_decode_attn.json`` at the repo root (the decode-step perf
trajectory future PRs regress against).

  PYTHONPATH=src python benchmarks/decode_attn.py
  PYTHONPATH=src python -m benchmarks.run --only decode_attn
"""
from __future__ import annotations

import argparse
import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.kernels.paged_attention import paged_attention_decode
from repro.kernels.paged_attention.ref import paged_decode_ref
from repro.models.attention import _paged_view, _sdpa, PagedKVCache
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import ServeConfig, ServeEngine, pack_prompts


# deployment-target shapes: 8 slots, GQA 2:1, 64-dim heads, 16-token
# blocks, a 32-block table (max_len 512)
B, KVH, G, HD, BS, W = 8, 2, 2, 64, 16, 32
DTYPE_BYTES = 4  # fp32 pool (bf16 halves both sides equally)


def _setup(resident: int, key):
    n_blocks = 1 + B * W
    kk, kv, kq, kt = jax.random.split(key, 4)
    pool_k = jax.random.normal(kk, (n_blocks, KVH, BS, HD), jnp.float32)
    pool_v = jax.random.normal(kv, (n_blocks, KVH, BS, HD), jnp.float32)
    q = jax.random.normal(kq, (B, KVH * G, HD), jnp.float32)
    # each slot owns `resident` distinct non-scratch blocks; the dead table
    # extent points at scratch block 0, as the engine leaves it
    table = np.zeros((B, W), np.int32)
    perm = np.asarray(jax.random.permutation(kt, n_blocks - 1)) + 1
    for b in range(B):
        table[b, :resident] = perm[b * resident:(b + 1) * resident] \
            if (b + 1) * resident <= perm.size else perm[:resident]
    # mid-block fill: the last resident block is partially used
    kv_len = jnp.full((B,), resident * BS - 3, jnp.int32)
    return pool_k, pool_v, q, jnp.asarray(table), kv_len


def _time(fn, repeats=5):
    jax.block_until_ready(fn())  # warm the jit cache
    best = min(
        (lambda t0: (jax.block_until_ready(fn()), time.time() - t0)[1])(time.time())
        for _ in range(repeats)
    )
    return best


def bench_cell(resident: int, log=print):
    key = jax.random.PRNGKey(resident)
    pool_k, pool_v, q, table, kv_len = _setup(resident, key)

    @jax.jit
    def baseline(q, pool_k, pool_v, table, kv_len):
        k_log, v_log = _paged_view(PagedKVCache(pool_k, pool_v), table)
        return _sdpa(q[:, :, None], k_log, v_log, causal=False, window=0,
                     kv_len=kv_len)[:, :, 0]

    def streamed():
        return paged_attention_decode(q, pool_k, pool_v, table, kv_len)

    base_out = baseline(q, pool_k, pool_v, table, kv_len)
    stream_out = streamed()
    ref_out = paged_decode_ref(q, pool_k, pool_v, table, kv_len)
    max_err_vs_base = float(jnp.max(jnp.abs(stream_out - base_out)))
    max_err_vs_ref = float(jnp.max(jnp.abs(stream_out - ref_out)))
    parity = max_err_vs_base < 2e-5 and max_err_vs_ref < 2e-5

    t_base = _time(lambda: baseline(q, pool_k, pool_v, table, kv_len))
    t_stream = _time(streamed)

    # modeled per-step KV HBM traffic (bytes), per the module docstring
    per_pos = KVH * HD * DTYPE_BYTES * 2  # K + V
    bytes_base = 3 * B * W * BS * per_pos
    live_blocks = -(-int(kv_len[0]) // BS)
    bytes_stream = B * live_blocks * BS * per_pos
    cell = {
        "batch": B, "kv_heads": KVH, "gqa_group": G, "head_dim": HD,
        "block_size": BS, "table_blocks": W, "resident_blocks": resident,
        "kv_len": int(kv_len[0]),
        "modeled_step_bytes_gathered": bytes_base,
        "modeled_step_bytes_streamed": bytes_stream,
        "modeled_step_speedup": bytes_base / bytes_stream,
        "measured_cpu_gathered_s": t_base,
        "measured_cpu_streamed_interpret_s": t_stream,
        "parity_ok": bool(parity),
        "max_abs_err_vs_baseline": max_err_vs_base,
    }
    log(f"decode_attn,resident={resident}/{W},modeled_speedup="
        f"{cell['modeled_step_speedup']:.2f}x,parity={parity},"
        f"cpu_gathered={t_base * 1e3:.2f}ms,"
        f"cpu_streamed_interpret={t_stream * 1e3:.1f}ms")
    return cell


def _engine_tokens(model, params, prompts, attn_impl):
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=len(prompts), max_len=28, chunk_steps=4, kv_block_size=8,
        attn_impl=attn_impl, astra_accounting=False))
    return [o.tokens for o in eng.generate_batch(prompts, 8)]


def token_identity(log=print):
    """Engine-level: the streamed kernel must be invisible to outputs,
    under exact numerics and under a PTQ-calibrated int8 plan (whose
    qk/pv sites stay exact, so the kernel routes)."""
    cfg = get_arch("stablelm-1.6b").reduced()
    key = jax.random.PRNGKey(0)
    params = Model(cfg, ModelOptions()).init(key)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (l,), dtype=np.int32)
               for l in (6, 11, 16)]
    results = {}
    for name, model in (
        ("exact", Model(cfg, ModelOptions())),
        ("calibrated_int8",
         Model(cfg, ModelOptions(plan="int8")).calibrate(
             params, {"tokens": pack_prompts(prompts, cfg)[0]})),
    ):
        toks = {impl: _engine_tokens(model, params, prompts, impl)
                for impl in ("naive", "flash")}
        same = all(np.array_equal(a, b)
                   for a, b in zip(toks["naive"], toks["flash"]))
        results[name] = bool(same)
        log(f"decode_attn,engine tokens identical ({name})={same}")
    return results


def run(log=print):
    log("# decode-attention step: gathered _paged_view+_sdpa vs streamed kernel")
    cells = [bench_cell(r, log=log) for r in (8, 16, 32)]
    identity = token_identity(log=log)
    qualifying = [c for c in cells if c["resident_blocks"] >= 8]
    worst = min(c["modeled_step_speedup"] for c in qualifying)
    ok = (worst >= 2.0 and all(c["parity_ok"] for c in cells)
          and all(identity.values()))
    log(f"decode_attn,min modeled step speedup at >=8 resident blocks="
        f"{worst:.2f}x (>=2.0),{'PASS' if ok else 'FAIL'}")
    return {
        "cells": cells,
        "claim": ">=2x lower decode-attention step cost (modeled KV HBM "
                 "traffic at the deployment target) at >=8 resident "
                 "blocks/slot, token-identical outputs under exact and "
                 "PTQ-calibrated plans",
        "speedup": worst,
        "tokens_identical": identity,
        "ref_validated": all(c["parity_ok"] for c in cells),
        "note": "measured_cpu_* fields time this host's XLA (baseline) vs "
                "interpret-mode Pallas (streamed); the interpreter is a "
                "correctness vehicle, not the performance target",
        "claim_pass": bool(ok),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", help="extra copy of the results")
    args = ap.parse_args(argv)
    out = run()
    path = os.path.join(REPO_ROOT, "BENCH_decode_attn.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
