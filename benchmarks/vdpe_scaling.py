"""Fig. 4 reproduction: VDPE scalability — OAGs per wavelength.

Two sub-tables: (a) optics budget per wavelength vs lane count (laser power,
loss, SNR, accumulated shot noise); (b) end-to-end stochastic-matmul error
vs lane count with the noise model on, showing the 1024-lane operating
point keeps relative error at the quantization floor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.photonics import PhotonicParams, vdpe_scalability_table
from repro.core.quant import quantize
from repro.core.vdpe import VDPEConfig, sc_matmul_error

LANES = (64, 128, 256, 512, 1024, 2048)


def run(log=print):
    p = PhotonicParams()
    rows = vdpe_scalability_table(p, LANES)
    log("# Fig4a: per-wavelength optics budget")
    log("vdpe_scaling,lanes,loss_db,laser_mw,laser_wall_mw,sigma_popcount,snr_db")
    for r in rows:
        log(f"vdpe_scaling,{r['lanes']},{r['loss_db']:.2f},{r['laser_mw']:.3f},"
            f"{r['laser_wall_mw']:.3f},{r['sigma_popcount']:.2f},{r['snr_db']:.1f}")

    log("# Fig4b: end-to-end SC matmul relative error vs lanes (noise + ADC)")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((32, 2048)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((2048, 16)), jnp.float32)
    exact = x @ w
    xq, wq = quantize(x), quantize(w, axis=0)
    errs = {}
    for lanes in LANES:
        e = sc_matmul_error(
            xq, wq, VDPEConfig(lanes=lanes, noisy=True), exact, key=jax.random.PRNGKey(1)
        )
        errs[lanes] = e
        log(f"vdpe_scaling_err,{lanes},rel_err={e:.4f}")
    ok = errs[1024] < 0.05
    log(f"vdpe_scaling,1024-lane operating point rel_err={errs[1024]:.4f},"
        f"{'PASS' if ok else 'FAIL'}")
    return {"budget": rows, "errors": {str(k): float(v) for k, v in errs.items()},
            "claim_pass": bool(ok)}


if __name__ == "__main__":
    run()
