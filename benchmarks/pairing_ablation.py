"""Beyond-paper ablation: stream-pairing policies for the OSSM array.

The AND-gate product estimator is exact only when the two streams are
*decorrelated*.  This table quantifies each pairing on one GEMM:

  thermometer x bresenham  — deterministic low-discrepancy (our default);
  lfsr x bresenham         — paper-faithful classic SC (LFSR comparator);
  lfsr x lfsr (same seed)  — pathologically CORRELATED: AND of identically-
                             ordered streams computes min(m_x,m_w), not the
                             product — the failure mode ASTRA's staggered
                             B-to-S seeds exist to prevent;
  lfsr x lfsr (phase 17)   — decorrelated by phase stagger (hardware fix).

Also sweeps the noisy VDPE (shot noise + 8-bit output ADC) on the default
pairing, at the paper's 1024-lane operating point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ossm import sc_matmul_value
from repro.core.quant import quantize
from repro.core.vdpe import VDPEConfig, sc_matmul_error


def _pair_error(xq, wq, exact, x_gen, w_gen):
    out = sc_matmul_value(xq, wq, x_gen, w_gen)
    return float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))


def run(log=print):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((32, 96)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((96, 16)), jnp.float32)
    exact = x @ w
    xq, wq = quantize(x), quantize(w, axis=0)

    log("# OSSM stream-pairing ablation (rel L2 error of one GEMM)")
    log("pairing_ablation,pairing,rel_err")
    rows = {}
    for name, (xg, wg) in {
        "thermometerxbresenham(default)": ("thermometer", "bresenham"),
        "lfsrxbresenham(paper)": ("lfsr", "bresenham"),
        "thermometerxlfsr": ("thermometer", "lfsr"),
        "lfsrxlfsr_same_seed(CORRELATED)": ("lfsr", "lfsr"),
    }.items():
        e = _pair_error(xq, wq, exact, xg, wg)
        rows[name] = e
        log(f"pairing_ablation,{name},{e:.4f}")

    # noisy VDPE at the paper operating point, default pairing
    e_noisy = sc_matmul_error(
        xq, wq, VDPEConfig(lanes=1024, noisy=True), exact, key=jax.random.PRNGKey(0)
    )
    rows["default+shot_noise+adc8"] = float(e_noisy)
    log(f"pairing_ablation,default+shot_noise+adc8,{e_noisy:.4f}")

    ok = (
        rows["thermometerxbresenham(default)"] <= rows["lfsrxbresenham(paper)"] + 1e-6
        and rows["lfsrxlfsr_same_seed(CORRELATED)"] > 3 * rows["lfsrxbresenham(paper)"]
    )
    log(f"pairing_ablation,decorrelation-matters,{'PASS' if ok else 'FAIL'}")
    return {"errors": rows, "claim_pass": bool(ok)}


if __name__ == "__main__":
    run()
