"""B-to-S converter properties: every generator must emit EXACTLY m ones."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.bitstream import (
    LFSR_ORDER, N_WORDS, STREAM_LEN, encode_signed, pack_bits,
    popcount, stream_bits, unpack_bits,
)

GENERATORS = ("thermometer", "bresenham", "lfsr")


@pytest.mark.parametrize("gen", GENERATORS)
def test_exact_density_all_magnitudes(gen):
    mags = jnp.arange(0, 128)
    bits = stream_bits(mags, gen)  # [128, 128]
    counts = np.asarray(bits.sum(-1))
    np.testing.assert_array_equal(counts, np.arange(128))


@pytest.mark.parametrize("gen", GENERATORS)
@pytest.mark.parametrize("phase", [0, 1, 17, 127])
def test_phase_preserves_density(gen, phase):
    mags = jnp.asarray([0, 1, 63, 64, 127])
    counts = np.asarray(stream_bits(mags, gen, phase).sum(-1))
    np.testing.assert_array_equal(counts, [0, 1, 63, 64, 127])


def test_lfsr_order_is_permutation():
    assert sorted(LFSR_ORDER) == list(range(128))


def test_pack_unpack_roundtrip(rng):
    bits = jnp.asarray(rng.integers(0, 2, (5, 7, STREAM_LEN)), jnp.int32)
    packed = pack_bits(bits)
    assert packed.shape == (5, 7, N_WORDS) and packed.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(unpack_bits(packed)), np.asarray(bits))


def test_popcount_matches_bitsum(rng):
    bits = jnp.asarray(rng.integers(0, 2, (9, STREAM_LEN)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(popcount(pack_bits(bits))), np.asarray(bits.sum(-1))
    )


@settings(max_examples=40, deadline=None)
@given(st.integers(-127, 127), st.sampled_from(GENERATORS))
def test_property_encode_signed(q, gen):
    packed, sign = encode_signed(jnp.asarray([q], jnp.int8), gen)
    assert int(sign[0]) == (-1 if q < 0 else 1)
    assert int(popcount(packed)[0]) == abs(q)


def test_thermometer_is_prefix():
    bits = np.asarray(stream_bits(jnp.asarray([37]), "thermometer"))[0]
    assert bits[:37].all() and not bits[37:].any()


def test_bresenham_spacing_is_even():
    # m ones in 128 slots: max gap between ones <= ceil(128/m) + 1
    for m in (3, 17, 64, 100):
        bits = np.asarray(stream_bits(jnp.asarray([m]), "bresenham"))[0]
        pos = np.flatnonzero(bits)
        gaps = np.diff(pos)
        assert gaps.max() <= int(np.ceil(128 / m)) + 1
