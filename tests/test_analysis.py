"""repro.analysis: planted violations, clean negatives, suppressions, CLI.

Each checker gets (a) a tmp mini-repo fixture with one planted violation
it must find and (b) a clean fixture it must stay silent on — so a
checker that silently stops matching fails CI here, not six PRs later.
The meta-test at the bottom pins the real repo itself lint-clean under
``--strict``: the linter gates CI (.github/workflows/ci.yml §lint), so
the tree must never commit a violation without a justified suppression.
"""
import json
import os
import textwrap

from repro.analysis import CHECKERS, run_analysis
from repro.analysis.__main__ import main as cli_main

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_repo(tmp_path, files):
    (tmp_path / "pyproject.toml").write_text("[project]\nname = 'fixture'\n")
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return tmp_path


def lint(tmp_path, check=None, strict=False):
    findings, _ = run_analysis([str(tmp_path / "src")], root=str(tmp_path),
                               strict=strict)
    if check is not None:
        findings = [f for f in findings if f.check == check]
    return findings


def test_registry_has_the_contracted_checkers():
    assert set(CHECKERS) >= {
        "trace-purity", "pallas-hazards", "kernel-contract",
        "site-grammar", "config-surface", "determinism-gates",
        "swallowed-exceptions",
    }
    for c in CHECKERS.values():
        assert c.doc, f"checker {c.name} needs a one-line docstring"


# ------------------------------------------------------------ trace-purity
def test_trace_purity_planted(tmp_path):
    make_repo(tmp_path, {
        "src/repro/serve/hot.py": """
            import time
            import numpy as np

            def stamp():
                return time.time()

            def jitter():
                return np.random.rand(3)
        """,
    })
    found = lint(tmp_path, "trace-purity")
    assert {f.line for f in found} == {6, 9}
    assert any("time.time" in f.message for f in found)
    assert any("numpy.random" in f.message for f in found)


def test_trace_purity_resolves_import_aliases(tmp_path):
    make_repo(tmp_path, {
        "src/repro/models/m.py": """
            import numpy.random as nr
            from time import monotonic

            def f():
                return nr.default_rng(), monotonic()
        """,
    })
    msgs = [f.message for f in lint(tmp_path, "trace-purity")]
    assert any("numpy.random" in m for m in msgs)
    assert any("from time import monotonic" in m for m in msgs)


# ---------------------------------------------------- swallowed-exceptions
def test_swallowed_exceptions_planted(tmp_path):
    make_repo(tmp_path, {
        "src/repro/serve/sup.py": """
            def drive(engine):
                try:
                    engine.step()
                except:
                    pass

            def poll(engines):
                for e in engines:
                    try:
                        e.step()
                    except (ValueError, Exception):
                        continue
        """,
        "src/repro/runtime/loop.py": """
            def run(step):
                try:
                    step()
                except BaseException:
                    ...
        """,
    })
    found = lint(tmp_path, "swallowed-exceptions")
    assert {(f.path, f.line) for f in found} == {
        ("src/repro/serve/sup.py", 5), ("src/repro/serve/sup.py", 12),
        ("src/repro/runtime/loop.py", 5),
    }
    assert any("bare 'except:'" in f.message for f in found)


def test_swallowed_exceptions_clean_and_scoped(tmp_path):
    make_repo(tmp_path, {
        # acting handlers and narrow swallows are the sanctioned patterns
        "src/repro/serve/ok.py": """
            import logging

            def drive(engine, log=logging.getLogger("x")):
                try:
                    engine.step()
                except Exception as e:
                    log.warning("step failed: %s", e)
                    raise
                try:
                    engine.poll()
                except KeyError:
                    pass
        """,
        # outside serve/runtime the checker does not apply at all
        "src/repro/traffic/other.py": """
            def f():
                try:
                    g()
                except:
                    pass
        """,
    })
    assert lint(tmp_path, "swallowed-exceptions") == []


def test_trace_purity_clean_on_injected_clock_and_keys(tmp_path):
    make_repo(tmp_path, {
        "src/repro/serve/cold.py": """
            import jax

            def step(clock, key):
                now = clock()
                key, sub = jax.random.split(key)
                return now, jax.random.uniform(sub, (2,))
        """,
        # out of scope entirely: launch scripts may read the wall clock
        "src/repro/launch/timed.py": "import time\nT0 = time.time()\n",
    })
    assert lint(tmp_path, "trace-purity") == []


# ---------------------------------------------------------- pallas-hazards
PALLAS_BAD = """
    from jax.experimental import pallas as pl

    def body(x_ref, o_ref):
        i = pl.program_id(0)

        @pl.when(i < 4)
        def _():
            j = pl.program_id(1)
            o_ref[0] = x_ref[j]
"""


def test_pallas_hazards_planted_program_id_in_when(tmp_path):
    make_repo(tmp_path, {"src/repro/kernels/fake/kernel.py": PALLAS_BAD})
    found = lint(tmp_path, "pallas-hazards")
    assert any("no lowering rule" in f.message for f in found)


def test_pallas_hazards_planted_pid_indexed_subscript(tmp_path):
    make_repo(tmp_path, {
        "src/repro/kernels/fake/kernel.py": """
            from jax.experimental import pallas as pl

            def body(scales_ref, o_ref):
                i = pl.program_id(0)

                @pl.when(i > 0)
                def _():
                    o_ref[0] = scales_ref[i]
        """,
    })
    found = lint(tmp_path, "pallas-hazards")
    assert any("program_id-bound" in f.message for f in found)


def test_pallas_hazards_planted_gather(tmp_path):
    make_repo(tmp_path, {
        "src/repro/kernels/fake/ops.py":
            "import jax.numpy as jnp\n\n"
            "def op(kv, idx):\n    return jnp.take(kv, idx, axis=0)\n",
    })
    found = lint(tmp_path, "pallas-hazards")
    assert any("gather-free" in f.message for f in found)


def test_pallas_hazards_clean_when_hoisted(tmp_path):
    make_repo(tmp_path, {
        "src/repro/kernels/fake/kernel.py": """
            from jax.experimental import pallas as pl

            def body(scales_ref, o_ref):
                i = pl.program_id(0)
                s = scales_ref[i]  # hoisted above the cond

                @pl.when(i > 0)
                def _():
                    o_ref[0] = s
        """,
        # gathers are fine in the oracle
        "src/repro/kernels/fake/ref.py":
            "import jax.numpy as jnp\n\n"
            "def op_ref(kv, idx):\n    return jnp.take(kv, idx, axis=0)\n",
    })
    assert lint(tmp_path, "pallas-hazards") == []


# --------------------------------------------------------- kernel-contract
FULL_TRIO = {
    "src/repro/kernels/foo/__init__.py": "from repro.kernels.foo.ops import foo\n",
    "src/repro/kernels/foo/kernel.py": "def _body(ref):\n    pass\n",
    "src/repro/kernels/foo/ops.py": "def foo(x, bm=8):\n    return x\n",
    "src/repro/kernels/foo/ref.py": "def foo_ref(x):\n    return x\n",
    "tests/test_foo.py": "from repro.kernels.foo import foo\n"
                         "from repro.kernels.foo.ref import foo_ref\n",
}


def test_kernel_contract_planted_missing_ref(tmp_path):
    files = {k: v for k, v in FULL_TRIO.items()
             if "ref.py" not in k or "tests" in k}
    make_repo(tmp_path, files)
    found = lint(tmp_path, "kernel-contract")
    assert any("missing ['ref.py']" in f.message for f in found)


def test_kernel_contract_planted_signature_drift(tmp_path):
    files = dict(FULL_TRIO)
    files["src/repro/kernels/foo/ref.py"] = \
        "def foo_ref(x, scale):\n    return x * scale\n"
    make_repo(tmp_path, files)
    found = lint(tmp_path, "kernel-contract")
    assert any("['scale']" in f.message for f in found)


def test_kernel_contract_planted_untested_package(tmp_path):
    files = {k: v for k, v in FULL_TRIO.items() if "tests" not in k}
    make_repo(tmp_path, files)
    found = lint(tmp_path, "kernel-contract")
    assert any("no module under tests/" in f.message for f in found)


def test_kernel_contract_clean(tmp_path):
    make_repo(tmp_path, FULL_TRIO)
    assert lint(tmp_path, "kernel-contract") == []


# ------------------------------------------------------------ site-grammar
def test_site_grammar_planted_typo(tmp_path):
    make_repo(tmp_path, {
        "src/repro/models/routing.py": 'RULES = {"L0.attn.qq": "int8"}\n',
    })
    found = lint(tmp_path, "site-grammar")
    assert [f.line for f in found] == [1]
    assert "L0.attn.qq" in found[0].message


def test_site_grammar_planted_dead_glob(tmp_path):
    make_repo(tmp_path, {
        "src/repro/models/routing.py": 'DYN = "*.qk|*.pvv"\n',
    })
    found = lint(tmp_path, "site-grammar")
    assert len(found) == 1 and "*.pvv" in found[0].message


def test_site_grammar_clean(tmp_path):
    make_repo(tmp_path, {
        "src/repro/models/routing.py": """
            CONCRETE = "L31.mlstm.qkv"
            DYN = "*.qk|*.pv"
            KV = "L0.kv.k"
            HEAD = "lm_head"
            NOT_SITES = ("*.json", "a|b", "some text")
        """,
    })
    assert lint(tmp_path, "site-grammar") == []


# ---------------------------------------------------------- config-surface
SURFACE_CLEAN = {
    "src/repro/serve/engine.py": "class ServeConfig:\n    max_slots: int = 8\n",
    "src/repro/serve/frontend.py":
        "class FrontendConfig:\n    max_queue_depth: int = 4\n",
    "src/repro/models/transformer.py":
        "class ModelOptions:\n    plan: str = ''\n    remat: bool = True\n",
    "src/repro/launch/flags.py": """
        FIELD_FLAGS = {
            "ServeConfig.max_slots": "--max-slots",
            "FrontendConfig.max_queue_depth": "--max-queue",
            "ModelOptions.plan": "--plan",
        }
        INTERNAL_FIELDS = {
            "ModelOptions.remat": "training-only knob",
        }

        def add_serve_flags(ap):
            ap.add_argument("--max-slots", type=int)
            ap.add_argument("--max-queue", type=int)
            ap.add_argument("--plan")
    """,
    "docs/SERVING.md": "Knobs: max_slots, max_queue_depth, plan.\n",
}


def test_config_surface_clean(tmp_path):
    make_repo(tmp_path, SURFACE_CLEAN)
    assert lint(tmp_path, "config-surface") == []


def test_config_surface_planted_unmapped_field(tmp_path):
    files = dict(SURFACE_CLEAN)
    files["src/repro/serve/engine.py"] = (
        "class ServeConfig:\n    max_slots: int = 8\n"
        "    kv_pool_blocks: int = 0\n")
    make_repo(tmp_path, files)
    found = lint(tmp_path, "config-surface")
    assert any("ServeConfig.kv_pool_blocks" in f.message
               and "neither reachable" in f.message for f in found)


def test_config_surface_planted_unregistered_flag(tmp_path):
    files = dict(SURFACE_CLEAN)
    files["src/repro/launch/flags.py"] = SURFACE_CLEAN[
        "src/repro/launch/flags.py"].replace(
        '            ap.add_argument("--max-slots", type=int)\n', "")
    make_repo(tmp_path, files)
    found = lint(tmp_path, "config-surface")
    assert any("no \nadd_argument" not in f.message
               and "add_argument('--max-slots'" in f.message.replace('"', "'")
               for f in found)


def test_config_surface_planted_stale_registry_entry(tmp_path):
    files = dict(SURFACE_CLEAN)
    files["src/repro/serve/frontend.py"] = \
        "class FrontendConfig:\n    queue_depth_cap: int = 4\n"
    make_repo(tmp_path, files)
    found = lint(tmp_path, "config-surface")
    assert any("no longer" in f.message for f in found)
    assert any("FrontendConfig.queue_depth_cap" in f.message for f in found)


def test_config_surface_planted_undocumented_field(tmp_path):
    files = dict(SURFACE_CLEAN)
    files["docs/SERVING.md"] = "Knobs: max_slots, max_queue_depth.\n"
    make_repo(tmp_path, files)
    found = lint(tmp_path, "config-surface")
    assert any("ModelOptions.plan" in f.message and "document" in f.message
               for f in found)


# ------------------------------------------------------- determinism-gates
def test_determinism_gates_planted(tmp_path):
    make_repo(tmp_path, {
        "src/repro/serve/warmup.py": """
            from repro.serve.prefix_tree import RadixPrefixTree

            def build(block_size):
                return RadixPrefixTree(block_size)
        """,
    })
    found = lint(tmp_path, "determinism-gates")
    assert len(found) == 1 and "prefix reuse" in found[0].message


def test_determinism_gates_clean_when_gated_or_defining(tmp_path):
    make_repo(tmp_path, {
        "src/repro/serve/warmup.py": """
            from repro.serve.engine import _kv_deterministic
            from repro.serve.prefix_tree import RadixPrefixTree

            def build(model, block_size):
                if not _kv_deterministic(model):
                    return None
                return RadixPrefixTree(block_size)
        """,
        # the defining module may exercise its own constructor
        "src/repro/serve/prefix_tree.py": """
            class RadixPrefixTree:
                def __init__(self, block_size):
                    self.block_size = block_size

            _EMPTY = RadixPrefixTree(1)
        """,
    })
    assert lint(tmp_path, "determinism-gates") == []


# ------------------------------------------------------------ suppressions
def test_line_suppression_silences_one_line(tmp_path):
    make_repo(tmp_path, {
        "src/repro/serve/hot.py": """
            import time

            def stamp():
                return time.time()  # repro-lint: disable=trace-purity -- fixture

            def other():
                return time.monotonic()
        """,
    })
    found = lint(tmp_path, "trace-purity")
    assert [f.line for f in found] == [8]  # only the unsuppressed read


def test_file_suppression_silences_whole_file(tmp_path):
    make_repo(tmp_path, {
        "src/repro/serve/hot.py": """
            # repro-lint: disable=trace-purity -- fixture-wide waiver
            import time

            def stamp():
                return time.time()
        """,
    })
    assert lint(tmp_path, "trace-purity") == []


def test_strict_polices_suppressions(tmp_path):
    make_repo(tmp_path, {
        "src/repro/serve/hot.py": """
            import time
            T = time.time  # repro-lint: disable=trace-purity
            U = 1  # repro-lint: disable=not-a-check -- bogus name
        """,
    })
    assert lint(tmp_path, "suppression", strict=False) == []
    strict = lint(tmp_path, "suppression", strict=True)
    assert any("without justification" in f.message for f in strict)
    assert any("unknown check" in f.message for f in strict)
    # the justified-but-unknown suppression must not hide real findings
    assert lint(tmp_path, "trace-purity", strict=True) == []


# --------------------------------------------------------------------- CLI
def test_cli_exit_codes_and_json(tmp_path, capsys):
    make_repo(tmp_path, {
        "src/repro/serve/hot.py": "import time\nT = time.time\n",
    })
    rc = cli_main([str(tmp_path / "src"), "--root", str(tmp_path), "--json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert [f["check"] for f in out["findings"]] == ["trace-purity"]
    assert out["stats"]["counts"] == {"trace-purity": 1}

    (tmp_path / "src/repro/serve/hot.py").write_text("X = 1\n")
    report = tmp_path / "artifacts" / "lint.json"
    rc = cli_main([str(tmp_path / "src"), "--root", str(tmp_path),
                   "--json-out", str(report)])
    capsys.readouterr()
    assert rc == 0
    assert json.loads(report.read_text())["findings"] == []


def test_cli_rejects_unknown_disable_and_paths(tmp_path, capsys):
    assert cli_main(["--list-checks"]) == 0
    capsys.readouterr()
    assert cli_main([str(tmp_path / "nope")]) == 2
    assert cli_main(["--disable", "bogus", str(tmp_path)]) == 2


def test_disable_skips_checker(tmp_path):
    make_repo(tmp_path, {
        "src/repro/serve/hot.py": "import time\nT = time.time\n",
    })
    findings, stats = run_analysis([str(tmp_path / "src")],
                                   root=str(tmp_path),
                                   disable=["trace-purity"])
    assert findings == []
    assert "trace-purity" not in stats["checkers"]


def test_parse_errors_are_findings(tmp_path):
    make_repo(tmp_path, {"src/repro/serve/broken.py": "def f(:\n"})
    findings, _ = run_analysis([str(tmp_path / "src")], root=str(tmp_path))
    assert [f.check for f in findings] == ["parse"]


# ---------------------------------------------------------------- meta-test
def test_real_repo_is_lint_clean_under_strict():
    """The gate CI enforces: the actual tree lints clean with >= 6 active
    checkers, so any reintroduced violation fails here first."""
    findings, stats = run_analysis(
        [os.path.join(REPO_ROOT, "src")], root=REPO_ROOT, strict=True)
    assert findings == [], "\n".join(f.render() for f in findings)
    assert len(stats["checkers"]) >= 6
