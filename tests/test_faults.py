"""Fault-isolated serving (docs/SERVING.md §Fault tolerance).

The load-bearing claims:

* **isolation** — an injected fault (step error, non-finite logits, pool
  pressure) quarantines only the offending request; every unaffected
  request's token stream is bit-identical to a fault-free replay of the
  same trace, across the dense / paged+prefix / paged-no-prefix /
  paged-int8 layouts;
* **conservation** — nothing vanishes: ``offered == completed + rejected
  + faulted + cancelled`` at every quiescent point, and terminal fault
  outputs carry the right ``fault_reason``;
* **no leaks** — ``ServeEngine.audit()`` (pool refcounts vs slot tables
  vs prefix tree vs supervisor holds, device rows vs host state, outbox
  exactly-once) passes after every quarantine/cancel, and catches a
  planted leak;
* **recovery** — deadlines expire waiting *and* in-flight requests,
  ``cancel`` frees KV blocks mid-decode, and capped-backoff retry
  completes retryable faults token-identically to the fault-free run;
* **determinism** — the injector's seeded periodic schedule and the
  whole faulted replay are pure functions of their seeds on the virtual
  clock.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import (
    CANCELLED, DEADLINE_EXCEEDED, EngineSupervisor, FaultSpec, FrontendConfig,
    RETRYABLE_FAULTS, ServeConfig, ServeEngine, ServeFaultInjector,
    ServeFrontend, pack_prompts,
)
from repro.serve.faults import (
    FAULT_NONFINITE, FAULT_POOL_PRESSURE, FAULT_SLOW_STEP, FAULT_STEP_ERROR,
)
from repro.traffic import VirtualClock, generate_trace, replay_trace


def _model(arch="stablelm-1.6b", **red):
    cfg = dataclasses.replace(get_arch(arch).reduced(**red), dtype="float32")
    return Model(cfg, ModelOptions())


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    shape = (cfg.n_codebooks,) if cfg.n_codebooks else ()
    return [rng.integers(0, cfg.vocab, shape + (l,), dtype=np.int32)
            for l in lens]


@pytest.fixture(scope="module")
def model_params():
    model = _model()
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def calibrated():
    """Reduced stablelm under a calibrated int8 plan (KV scales baked),
    the paged-int8 leg of the chaos matrix."""
    cfg = dataclasses.replace(get_arch("stablelm-1.6b").reduced(),
                              dtype="float32")
    model = Model(cfg, ModelOptions(plan="int8"))
    params = model.init(jax.random.PRNGKey(0))
    cal_tokens, _ = pack_prompts(_prompts(cfg, (6, 10), seed=3), cfg)
    return model.calibrate(params, {"tokens": cal_tokens}), params


def _stack(model, params, schedule=(), retries=0, deadline=None, **cfg_over):
    """VirtualClock + engine + supervisor + front-end, fault-ready."""
    clk = VirtualClock()
    cfg_over.setdefault("max_slots", 3)
    cfg_over.setdefault("max_len", 64)
    cfg_over.setdefault("kv_block_size", 8)
    eng = ServeEngine(model, params, ServeConfig(
        chunk_steps=2, astra_accounting=False, **cfg_over), clock=clk)
    sup = EngineSupervisor(eng, ServeFaultInjector(schedule))
    fe = ServeFrontend(eng, FrontendConfig(max_retries=retries,
                                           default_deadline_s=deadline),
                       clock=clk, supervisor=sup)
    return fe, eng, sup, clk


def _trace(cfg, n=8, seed=1, rate=50.0):
    return generate_trace(suite="chat", rate_rps=rate, n=n, seed=seed,
                          vocab=cfg.vocab, n_codebooks=cfg.n_codebooks)


def _conserved(stats):
    return stats["submitted"] == (
        stats["completed"] + stats["rejected_queue_full"]
        + stats["rejected_queue_timeout"] + stats["faulted"]
        + stats["cancelled"] + stats["queue_depth"] + stats["in_flight"]
        + stats["retry_pending"])


# ----------------------------------------------------------- chaos matrix
_VARIANTS = [
    # (fixture, config overrides)
    ("model_params", {}),                                # paged + prefix
    ("model_params", {"prefix_cache": False}),           # paged, no prefix
    ("model_params", {"kv_block_size": 0}),              # dense
    ("calibrated", {"kv_quant": "int8"}),                # paged int8
]


@pytest.mark.parametrize(
    "fixture,over", _VARIANTS,
    ids=["paged-prefix", "paged-noprefix", "dense", "paged-int8"])
def test_chaos_replay_isolates_faults(request, fixture, over):
    """Seeded fault schedule x every KV layout: unaffected requests are
    token-identical to a fault-free replay, accounting conserves, and
    the final audit is clean."""
    model, params = request.getfixturevalue(fixture)
    trace = _trace(model.cfg, n=8, seed=2)
    fe0, eng0, sup0, _ = _stack(model, params, **over)
    r0 = replay_trace(fe0, trace, virtual_step_s=0.05)
    assert fe0.stats["completed"] == len(trace)
    ref = {rid: r0.outputs_by_id[rid].tokens for rid in r0.request_ids}

    schedule = ServeFaultInjector.periodic(
        n_steps=40, every=4,
        kinds=(FAULT_STEP_ERROR, FAULT_NONFINITE, FAULT_POOL_PRESSURE),
        seed=7).schedule
    fe1, eng1, sup1, _ = _stack(model, params, schedule, **over)
    r1 = replay_trace(fe1, trace, virtual_step_s=0.05)
    st = fe1.stats
    assert _conserved(st)
    assert sup1.stats["faults_injected"] > 0
    assert st["faulted"] > 0  # the schedule actually bit someone
    n_unaffected = 0
    for i, rid0 in enumerate(r0.request_ids):
        o1 = r1.outputs_by_id[r1.request_ids[i]]
        if o1.fault_reason is None and o1.reject_reason is None:
            n_unaffected += 1
            np.testing.assert_array_equal(o1.tokens, ref[rid0])
        # streamed chunks == terminal tokens, faulted or not
        np.testing.assert_array_equal(
            r1.token_streams[r1.request_ids[i]], o1.tokens)
    assert n_unaffected == st["completed"]
    rep = eng1.audit(external_refs=sup1.held_blocks)
    assert rep["leaked_blocks"] == 0 and rep["leaked_bytes"] == 0
    for o in r1.outputs_by_id.values():
        if o.fault_reason is not None:
            assert o.fault_reason in RETRYABLE_FAULTS


def test_chaos_replay_is_deterministic(model_params):
    """Same trace + same fault seed -> bit-identical faulted replay."""
    model, params = model_params
    trace = _trace(model.cfg, n=6, seed=4)
    sched = ServeFaultInjector.periodic(n_steps=30, every=5, seed=9).schedule
    runs = []
    for _ in range(2):
        fe, eng, sup, _ = _stack(model, params, sched)
        r = replay_trace(fe, trace, virtual_step_s=0.05)
        runs.append((fe.stats, sorted(
            (rid, o.fault_reason, o.tokens.tobytes())
            for rid, o in r.outputs_by_id.items())))
    assert runs[0] == runs[1]


# ----------------------------------------------- per-class fault targeting
def _run_batch_with_supervisor(model, params, schedule, lens=(6, 9, 12),
                               gen=10, **cfg_over):
    fe, eng, sup, _ = _stack(model, params, schedule, **cfg_over)
    for p in _prompts(model.cfg, lens, seed=5):
        fe.submit(p, gen)
    outs = fe.run()
    return outs, fe, eng, sup


def test_nonfinite_quarantines_only_the_victim(model_params):
    model, params = model_params
    ref, *_ = _run_batch_with_supervisor(model, params, ())
    sched = [FaultSpec(step=2, kind=FAULT_NONFINITE, slot=1)]
    outs, fe, eng, sup = _run_batch_with_supervisor(model, params, sched)
    faulted = [o for o in outs if o.fault_reason is not None]
    assert len(faulted) == 1
    assert faulted[0].fault_reason == FAULT_NONFINITE
    # the victim keeps its pre-fault stream only; the faulted chunk's
    # tokens are never emitted
    ref_by_id = {o.request_id: o for o in ref}
    want = ref_by_id[faulted[0].request_id].tokens
    assert faulted[0].gen_len < want.shape[-1]
    np.testing.assert_array_equal(
        faulted[0].tokens, want[..., : faulted[0].gen_len])
    for o in outs:
        if o.fault_reason is None:
            np.testing.assert_array_equal(o.tokens,
                                          ref_by_id[o.request_id].tokens)
    assert eng.audit(sup.held_blocks)["leaked_blocks"] == 0


def test_step_error_skips_chunk_bit_identically(model_params):
    model, params = model_params
    ref, *_ = _run_batch_with_supervisor(model, params, ())
    sched = [FaultSpec(step=3, kind=FAULT_STEP_ERROR, slot=0)]
    outs, fe, eng, sup = _run_batch_with_supervisor(model, params, sched)
    ref_by_id = {o.request_id: o for o in ref}
    faulted = [o for o in outs if o.fault_reason is not None]
    assert [o.fault_reason for o in faulted] == [FAULT_STEP_ERROR]
    for o in outs:
        if o.fault_reason is None:
            np.testing.assert_array_equal(o.tokens,
                                          ref_by_id[o.request_id].tokens)
    assert eng.audit(sup.held_blocks)["leaked_blocks"] == 0


def test_slow_step_changes_latency_not_tokens(model_params):
    model, params = model_params
    ref, fe0, *_ = _run_batch_with_supervisor(model, params, ())
    sched = [FaultSpec(step=2, kind=FAULT_SLOW_STEP, delay_s=1.5)]
    outs, fe, eng, sup = _run_batch_with_supervisor(model, params, sched)
    assert all(o.fault_reason is None for o in outs)
    ref_by_id = {o.request_id: o for o in ref}
    for o in outs:
        np.testing.assert_array_equal(o.tokens, ref_by_id[o.request_id].tokens)
    assert max(o.timing.wall_time_s for o in outs) > \
        max(o.timing.wall_time_s for o in ref)


def test_scrubbed_blocks_never_poison_later_tenants(model_params):
    """A NaN-quarantined slot's blocks are zeroed before release: a new
    request that reuses them must decode exactly as on a fresh engine."""
    model, params = model_params
    # tight pool so the released blocks are certainly reused
    sched = [FaultSpec(step=1, kind=FAULT_NONFINITE, slot=0)]
    fe, eng, sup, _ = _stack(model, params, sched, max_slots=1,
                             kv_pool_blocks=17, prefix_cache=False)
    p1, p2 = _prompts(model.cfg, (10, 7), seed=6)
    fe.submit(p1, 12)
    fe.submit(p2, 8)
    outs = fe.run()
    assert [o.fault_reason for o in outs
            if o.fault_reason is not None] == [FAULT_NONFINITE]
    fresh = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=64, kv_block_size=8, astra_accounting=False))
    [want] = fresh.generate_batch([p2], 8)
    got = [o for o in outs if o.fault_reason is None]
    np.testing.assert_array_equal(got[-1].tokens, want.tokens)
    assert eng.audit(sup.held_blocks)["leaked_blocks"] == 0


# ------------------------------------------------- cancel, deadline, retry
def test_cancel_frees_blocks_mid_decode(model_params):
    model, params = model_params
    fe, eng, sup, _ = _stack(model, params)
    rids = [fe.submit(p, 16) for p in _prompts(model.cfg, (8, 8), seed=7)]
    fe.pump()  # both admitted and decoding
    live_before = eng._pool.n_live
    assert fe.cancel(rids[0]) is True
    outs = fe.run()
    assert eng._pool.n_live < live_before
    by_id = {o.request_id: o for o in outs}
    assert by_id[rids[0]].fault_reason == CANCELLED
    assert by_id[rids[1]].fault_reason is None
    assert fe.stats["cancelled"] == 1 and fe.stats["completed"] == 1
    assert fe.cancel(12345) is False  # unknown id
    assert fe.cancel(rids[0]) is False  # already finished
    assert eng.audit(sup.held_blocks)["leaked_blocks"] == 0
    assert _conserved(fe.stats)


def test_cancel_waiting_request_never_reaches_engine(model_params):
    model, params = model_params
    fe, eng, sup, _ = _stack(model, params, max_slots=1)
    p = _prompts(model.cfg, (6, 6), seed=8)
    rid0 = fe.submit(p[0], 12)
    rid1 = fe.submit(p[1], 12)  # waits behind rid0 (one slot)
    assert fe.cancel(rid1) is True
    outs = fe.run()
    by_id = {o.request_id: o for o in outs}
    assert by_id[rid1].fault_reason == CANCELLED
    assert by_id[rid1].gen_len == 0
    assert by_id[rid0].fault_reason is None


def test_deadline_expires_waiting_and_inflight(model_params):
    model, params = model_params
    fe, eng, sup, clk = _stack(model, params, max_slots=1, deadline=0.4)
    p = _prompts(model.cfg, (6, 6), seed=9)
    rid0 = fe.submit(p[0], 40)  # long: will still be decoding at t=0.4
    rid1 = fe.submit(p[1], 4)   # waits behind rid0, expires in the queue
    while fe.busy():
        clk.advance(0.05)
        fe.pump()
    by_id = {o.request_id: o for o in fe.drain()}
    assert by_id[rid0].fault_reason == DEADLINE_EXCEEDED
    assert by_id[rid0].gen_len > 0  # partial stream kept
    assert by_id[rid1].fault_reason == DEADLINE_EXCEEDED
    assert by_id[rid1].gen_len == 0
    assert fe.stats["cancelled"] == 2
    assert eng.audit(sup.held_blocks)["leaked_blocks"] == 0


def test_retry_completes_token_identically(model_params):
    model, params = model_params
    trace = _trace(model.cfg, n=6, seed=11)
    fe0, *_ = _stack(model, params)
    r0 = replay_trace(fe0, trace, virtual_step_s=0.05)
    ref = {rid: r0.outputs_by_id[rid].tokens for rid in r0.request_ids}
    sched = [FaultSpec(step=2, kind=FAULT_NONFINITE, slot=0),
             FaultSpec(step=5, kind=FAULT_STEP_ERROR, slot=1)]
    fe, eng, sup, _ = _stack(model, params, sched, retries=2)
    r = replay_trace(fe, trace, virtual_step_s=0.05)
    st = fe.stats
    assert st["retries"] >= 1
    assert st["completed"] == len(trace) and st["faulted"] == 0
    for i, rid0 in enumerate(r0.request_ids):
        rid = r.request_ids[i]
        np.testing.assert_array_equal(r.outputs_by_id[rid].tokens, ref[rid0])
        # the withdrawn partial stream never double-counts (on_retry hook)
        np.testing.assert_array_equal(r.token_streams[rid],
                                      r.outputs_by_id[rid].tokens)
    assert eng.audit(sup.held_blocks)["leaked_blocks"] == 0


def test_retry_exhaustion_goes_terminal(model_params):
    model, params = model_params
    # fault every step: retries can never outrun the schedule
    sched = [FaultSpec(step=s, kind=FAULT_STEP_ERROR) for s in range(1, 200)]
    fe, eng, sup, _ = _stack(model, params, sched, retries=2, max_slots=1)
    [p] = _prompts(model.cfg, (6,), seed=12)
    rid = fe.submit(p, 8)
    [out] = fe.run()
    assert out.request_id == rid
    assert out.fault_reason == FAULT_STEP_ERROR
    assert fe.stats["retries"] == 2 and fe.stats["faulted"] == 1
    assert _conserved(fe.stats)


def test_pool_pressure_sheds_then_recovers(model_params):
    """A transient full-pool hold walks the ladder to shedding: the big
    queued request is failed as a terminal ``pool_pressure`` output while
    the in-flight small requests finish untouched; once the pressure is
    over, later submissions complete normally and the ladder relaxes."""
    model, params = model_params
    # hold every free block for 8 supervisor steps starting at step 1
    sched = [FaultSpec(step=1, kind=FAULT_POOL_PRESSURE, duration=8)]
    fe, eng, sup, clk = _stack(model, params, sched, max_slots=3,
                               kv_pool_blocks=25)
    small = _prompts(model.cfg, (6, 6), seed=13)
    rids = [fe.submit(p, 6) for p in small]
    fe.pump()  # the smalls admit before the hold lands
    clk.advance(0.05)
    # a request needing more blocks (7) than any one retirement can free
    # (2): with the hold pinning everything else, its admission stalls
    # every round and the ladder must walk flush -> no-admission -> shed
    [big] = _prompts(model.cfg, (30,), seed=14)
    rid_big = fe.submit(big, 26)
    outs = fe.run()
    by_id = {o.request_id: o for o in outs}
    assert by_id[rid_big].fault_reason == FAULT_POOL_PRESSURE
    assert by_id[rid_big].gen_len == 0  # shed from the queue, never ran
    assert all(by_id[r].fault_reason is None for r in rids)  # untouched
    names = [name for _, name in eng.stats()["degraded_transitions"]]
    assert names[:3] == ["flush_prefix", "no_prefix_admission", "shed_load"]
    # pressure over: a later submission completes and the ladder relaxes
    [late] = _prompts(model.cfg, (6,), seed=15)
    rid_late = fe.submit(late, 6)
    [out_late] = fe.run()
    assert out_late.request_id == rid_late and out_late.fault_reason is None
    assert eng.stats()["degraded_level"] != "shed_load"
    assert eng.audit(sup.held_blocks)["leaked_blocks"] == 0
    assert _conserved(fe.stats)


# --------------------------------------------------------- audit teeth
def test_audit_catches_planted_refcount_leak(model_params):
    model, params = model_params
    fe, eng, sup, _ = _stack(model, params)
    fe.submit(_prompts(model.cfg, (8,), seed=14)[0], 6)
    fe.pump()
    held = [b for b in eng._slot_blocks if b][0][0]
    eng._pool.incref(held)  # planted leak: a ref no holder explains
    with pytest.raises(RuntimeError, match="refcount drift"):
        eng.audit(sup.held_blocks)
    eng._pool.decref(held)
    fe.run()
    assert eng.audit(sup.held_blocks)["leaked_blocks"] == 0


def test_pool_check_consistent_catches_double_bookkeeping(model_params):
    model, params = model_params
    fe, eng, sup, _ = _stack(model, params)
    fe.submit(_prompts(model.cfg, (8,), seed=15)[0], 4)
    fe.pump()
    eng._pool._free.append(eng._pool._free[-1])  # duplicate free entry
    with pytest.raises(RuntimeError, match="duplicate"):
        eng._pool.check_consistent()
    eng._pool._free.pop()
    fe.run()


# ------------------------------------------------- config/spec validation
def test_fault_spec_and_injector_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(step=0, kind="power_surge")
    with pytest.raises(ValueError, match="timing"):
        FaultSpec(step=-1, kind=FAULT_STEP_ERROR)
    with pytest.raises(ValueError, match="timing"):
        FaultSpec(step=0, kind=FAULT_SLOW_STEP, delay_s=-0.1)
    inj = ServeFaultInjector.periodic(n_steps=20, every=5, seed=3)
    again = ServeFaultInjector.periodic(n_steps=20, every=5, seed=3)
    assert inj.schedule == again.schedule  # pure function of the seed
    assert [s.step for s in inj.schedule] == [4, 9, 14, 19]
    assert inj.pop(4) and not inj.pop(4)  # exactly-once delivery
    assert inj.n_pending == 3


def test_frontend_fault_config_validation(model_params):
    model, params = model_params
    with pytest.raises(ValueError, match="default_deadline_s"):
        FrontendConfig(default_deadline_s=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        FrontendConfig(max_retries=-1)
    with pytest.raises(ValueError, match="retry_backoff_s"):
        FrontendConfig(retry_backoff_s=-0.5)
    clk = VirtualClock()
    eng_a = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=32, astra_accounting=False), clock=clk)
    eng_b = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=32, astra_accounting=False), clock=clk)
    with pytest.raises(ValueError, match="different engine"):
        ServeFrontend(eng_a, supervisor=EngineSupervisor(eng_b))
    with pytest.raises(ValueError, match="deadline_s"):
        ServeFrontend(eng_a).submit(np.ones(4, np.int32), 2, deadline_s=-1.0)
