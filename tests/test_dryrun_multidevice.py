"""Multi-device sharding integration test (subprocess: 8 fake CPU devices).

The 512-device production dry-run runs out-of-process (launch/dryrun.py);
this test pins the same machinery — sharding rules, step builders,
collective parsing — on an 8-device (2,2,2) mesh with a tiny config, so a
sharding regression fails CI in seconds rather than at pod-launch time.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp

    from repro.configs import get_arch
    from repro.launch.dryrun import parse_collectives
    from repro.models.model import Model, input_specs
    from repro.models.transformer import ModelOptions
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    from repro.parallel.sharding import activation_mesh, batch_specs, param_specs

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_arch("qwen3-moe-30b-a3b").reduced()  # MoE: exercises EP + FSDP + TP
    model = Model(cfg, ModelOptions())
    param_shapes = model.param_shapes()
    p_shard = param_specs(param_shapes, mesh)
    opt_shapes = jax.eval_shape(adamw_init, param_shapes)
    o_shard = {
        "m": param_specs(opt_shapes["m"], mesh),
        "v": param_specs(opt_shapes["v"], mesh),
        "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }
    specs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    b_shard = batch_specs(specs, mesh)
    ocfg = AdamWConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch)[0])(params)
        p2, o2, stats = adamw_update(params, grads, opt_state, ocfg)
        return p2, o2, {"loss": loss, **stats}

    fn = jax.jit(train_step, in_shardings=(p_shard, o_shard, b_shard),
                 out_shardings=(p_shard, o_shard, None))
    with mesh, activation_mesh(mesh):
        lowered = fn.lower(param_shapes, opt_shapes, specs)
        compiled = lowered.compile()
        # actually execute on the 8 fake devices — numerics + shardings real
        params = jax.jit(model.init, out_shardings=p_shard)(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        tokens = jax.device_put(
            jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab), b_shard["tokens"])
        p2, o2, stats = fn(params, opt, {"tokens": tokens})

    coll = parse_collectives(compiled.as_text())
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    print(json.dumps({
        "loss": float(stats["loss"]),
        "collectives": sorted(coll),
        "flops": float(dict(ca).get("flops", 0.0)),
        "n_devices": jax.device_count(),
    }))
    """
)


@pytest.mark.slow
def test_train_step_shards_on_8_devices():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 8
    assert rec["flops"] > 0
    import math
    assert math.isfinite(rec["loss"]) and 0 < rec["loss"] < 20
    # FSDP + TP must produce real collectives in the step
    assert "all-reduce" in rec["collectives"]
    assert "all-gather" in rec["collectives"]
