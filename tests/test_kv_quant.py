"""Quantized paged KV cache: int8 blocks with calibrated static scales.

The cross-feature parity matrix for ``kv_quant="int8"`` (docs/SERVING.md
§KV quantization):

* **matrix** — every serve feature must be invisible on the quantized
  pool: {naive, flash} attention x {blocking, chunked} prefill x
  {prefix cache on, off} all produce token-identical outputs;
* **drift** — logit drift vs the fp cache stays under the *same*
  documented bounds the benchmark asserts (imported from
  ``benchmarks/kv_quant.py`` so the two cannot drift apart);
* **accounting** — ``engine.kv_stats`` byte figures are exact to the
  element count (int8 = 1 B/elem; modeled fp16 baseline = exactly 2x);
* **gates** — dynamic-scale plans, dense layouts, and uncalibrated KV
  scales are refused with ``ValueError`` (prefix reuse must stay legal:
  pooled KV has to be a pure function of the token path);
* **properties** — quantize/dequantize round-trip error <= scale/2 per
  element, scales strictly positive, and block scatter preserves
  quantized payloads bit-exactly (mid-block spans, ring wrap).
"""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:  # benchmarks/ is a repo-root namespace package
    sys.path.insert(0, ROOT)

from benchmarks.kv_quant import (
    KERNEL_DRIFT_BOUND, LOGIT_DRIFT_BOUND, kernel_drift, model_logit_drift,
)
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_arch
from repro.core.plan import MAG_MAX, ExecutionPlan, kv_sites
from repro.models.attention import (
    QuantPagedKVCache, _paged_write_span, _paged_write_token,
    init_paged_quant_cache, kv_dequantize, kv_quantize,
)
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import ServeConfig, ServeEngine, pack_prompts
from repro.serve.frontend import FrontendConfig, ServeFrontend

_QUIET = lambda *a, **k: None


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (l,), dtype=np.int32) for l in lens]


@pytest.fixture(scope="module")
def calibrated():
    """Reduced stablelm under a *calibrated* int8 plan: static act scales
    plus baked KV storage-site scales (the determinism gate's happy path)."""
    cfg = dataclasses.replace(get_arch("stablelm-1.6b").reduced(),
                              dtype="float32")
    model = Model(cfg, ModelOptions(plan="int8"))
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (6, 10), seed=3)
    cal_tokens, _ = pack_prompts(prompts, cfg)
    model = model.calibrate(params, {"tokens": cal_tokens})
    return model, params


def _engine(model, params, prompts, gen, **kw):
    kw.setdefault("max_len", max(p.shape[-1] for p in prompts) + gen + 1)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=len(prompts), chunk_steps=2, kv_block_size=4,
        kv_quant="int8", astra_accounting=False, **kw))
    return eng, eng.generate_batch(prompts, gen)


# ------------------------------------------------------ the parity matrix
_MATRIX = [
    # (attn_impl, prefill_chunk_tokens, prefix_cache)
    ("naive", 4, True),
    ("naive", 0, False),
    ("flash", 0, True),
    ("flash", 4, True),
    ("flash", 4, False),
]


@pytest.mark.parametrize("attn,chunk,prefix", _MATRIX,
                         ids=[f"{a}-{'chunked' if c else 'blocking'}-"
                              f"{'prefix' if p else 'noprefix'}"
                              for a, c, p in _MATRIX])
def test_matrix_features_invisible_on_quant_pool(calibrated, attn, chunk,
                                                 prefix):
    """Every cell of the feature matrix is token-identical to the plain
    quantized engine (naive attention, blocking prefill, prefix on):
    kernels, the chunked scheduler, and reuse never see different bits."""
    model, params = calibrated
    prompts = _prompts(model.cfg, (6, 10), seed=5)
    _, base = _engine(model, params, prompts, 4)
    _, outs = _engine(model, params, prompts, 4, attn_impl=attn,
                      prefill_chunk_tokens=chunk, prefix_cache=prefix)
    for b, o in zip(base, outs):
        np.testing.assert_array_equal(o.tokens, b.tokens)


def test_prefix_hit_replay_token_identical(calibrated):
    """Replaying the same prompts hits the interned int8 blocks and must
    reproduce the cold pass token for token (payload reuse is verbatim)."""
    model, params = calibrated
    prompts = _prompts(model.cfg, (9, 13), seed=6)
    eng, cold = _engine(model, params, prompts, 4)
    hit = eng.generate_batch(prompts, 4)
    assert eng.prefix_stats["hits"] > 0
    for c, h in zip(cold, hit):
        np.testing.assert_array_equal(h.tokens, c.tokens)


def test_frontend_streaming_token_identical(calibrated):
    """Per-token streaming through ServeFrontend on a quantized engine
    matches batch serving exactly."""
    model, params = calibrated
    prompts = _prompts(model.cfg, (6, 11), seed=7)
    gen = 5
    _, ref = _engine(model, params, prompts, gen)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=17, chunk_steps=2, kv_block_size=4,
        kv_quant="int8", astra_accounting=False))
    fe = ServeFrontend(eng, FrontendConfig())
    streams = [fe.stream(p, gen) for p in prompts]
    for s, r in zip(streams, ref):
        toks = list(s)
        assert s.finished and s.output is not None
        np.testing.assert_array_equal(np.stack(toks, axis=-1), r.tokens)


def test_composes_with_calibrated_mixed_plan(calibrated):
    """kv_quant rides along any calibrated plan (here the paper's hybrid
    mixed preset: int8 qk/pv + stochastic projections), and replays stay
    token-identical."""
    model, params = calibrated
    mixed = model.with_plan("mixed").calibrate(
        params, {"tokens": pack_prompts(_prompts(model.cfg, (8,), seed=8),
                                        model.cfg)[0]})
    prompts = _prompts(model.cfg, (7, 12), seed=9)
    eng, cold = _engine(mixed, params, prompts, 4)
    assert eng.kv_stats["kv_quant"] == "int8"
    hit = eng.generate_batch(prompts, 4)
    assert eng.prefix_stats["hits"] > 0
    for c, h in zip(cold, hit):
        np.testing.assert_array_equal(h.tokens, c.tokens)


# ------------------------------------------------------------------ drift
def test_first_step_logit_drift_bounded(calibrated):
    """Max |logits| drift fp-pool vs int8-pool over identical token paths
    stays under the documented bound (same code + constant as the
    benchmark, so the two assertions cannot diverge)."""
    model, params = calibrated
    prompts = _prompts(model.cfg, (9, 14), seed=10)
    drift = model_logit_drift(model, params, prompts, block=4, log=_QUIET)
    assert 0 < drift < LOGIT_DRIFT_BOUND


def test_kernel_decode_drift_bounded():
    """Kernel-level: same KV content through fp vs int8 pools with
    calibration-style scales stays under KERNEL_DRIFT_BOUND, and the
    round-trip error under scale/2 (asserted inside kernel_drift)."""
    res = kernel_drift(smoke=True, log=_QUIET)
    assert res["ok"], res
    assert res["kernel_decode_max_drift"] < KERNEL_DRIFT_BOUND


# ------------------------------------------------------------- accounting
def test_kv_stats_byte_accounting_exact(calibrated):
    """bytes_per_block is exact to the element count: int8 = 1 B/elem,
    host fp32 = 4 B/elem, and the modeled fp16 baseline is exactly 2x."""
    model, params = calibrated
    cfg = model.cfg
    prompts = _prompts(cfg, (6, 10), seed=11)
    eng_q, _ = _engine(model, params, prompts, 4)
    eng_fp = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=15, chunk_steps=2, kv_block_size=4,
        astra_accounting=False))
    eng_fp.generate_batch(prompts, 4)
    # stablelm: every layer is global attn -> one K + one V pool each of
    # [.., n_kv, block, hd] per layer
    elems = cfg.n_layers * 2 * cfg.n_kv_heads * 4 * cfg.head_dim
    q, fp = eng_q.kv_stats, eng_fp.kv_stats
    assert q["kv_quant"] == "int8" and fp["kv_quant"] == "none"
    assert q["bytes_per_block"] == elems          # int8: 1 byte/element
    assert fp["bytes_per_block"] == elems * 4     # host pools are float32
    assert (elems * 2) / q["bytes_per_block"] == 2.0  # vs modeled fp16
    for s, eng in ((q, eng_q), (fp, eng_fp)):
        assert s["pool_bytes"] == (s["pool_blocks"] - 1) * s["bytes_per_block"]
        assert s["live_bytes"] == s["live_blocks"] * s["bytes_per_block"]
        assert s["live_blocks"] == eng._pool.n_live


# ------------------------------------------------------------------ gates
def test_rejects_dynamic_scale_plan():
    """Uncalibrated int8 plans have batch-dependent act scales: pooled KV
    would not be a pure function of the token path.  Hard error, with the
    reason in the message."""
    cfg = dataclasses.replace(get_arch("stablelm-1.6b").reduced(),
                              dtype="float32")
    model = Model(cfg, ModelOptions(plan="int8"))
    params = model.init(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="deterministic"):
        ServeEngine(model, params, ServeConfig(
            max_slots=1, max_len=16, kv_block_size=4, kv_quant="int8"))
    # without kv_quant the same plan is allowed — reuse just turns off,
    # and the reason is surfaced in kv_stats
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=16, kv_block_size=4))
    assert eng._prefix is None
    assert "non-deterministic" in eng.kv_stats["prefix_cache_off_reason"]


def test_rejects_dense_layout(calibrated):
    model, params = calibrated
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, ServeConfig(
            max_slots=1, max_len=16, kv_block_size=0, kv_quant="int8"))


def test_rejects_missing_kv_scales(calibrated):
    """Static act scales alone are not enough: the plan must also carry
    the baked L{li}.kv.{k,v} storage-site scales."""
    model, params = calibrated
    static = model.with_plan(
        ExecutionPlan.from_spec({"default": {"mode": "int8",
                                             "act_scale": 0.05}}))
    assert static.plan.kv_scale(kv_sites(model.cfg)[0]) is None
    with pytest.raises(ValueError, match="calibrate"):
        ServeEngine(static, params, ServeConfig(
            max_slots=1, max_len=16, kv_block_size=4, kv_quant="int8"))


def test_rejects_unknown_kv_quant_mode(calibrated):
    model, params = calibrated
    with pytest.raises(ValueError, match="kv_quant"):
        ModelOptions(kv_quant="int4")
    with pytest.raises(ValueError, match="kv_quant"):
        ServeEngine(model, params, ServeConfig(
            max_slots=1, max_len=16, kv_block_size=4, kv_quant="fp8"))


def test_calibrated_kv_scales_cover_all_sites_and_are_positive(calibrated):
    model, _ = calibrated
    sites = kv_sites(model.cfg)
    assert sites and set(dict(model.plan.kv_scales)) == set(sites)
    for site in sites:
        vec = np.asarray(model.plan.kv_scale(site))
        assert vec.shape == (model.cfg.n_kv_heads,)
        assert np.all(vec > 0)  # strictly positive, even at zero absmax


# -------------------------------------------------------------- properties
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_quantize_roundtrip_error_half_scale(seed):
    """Calibration-style scales (per-head absmax/127) never clip, so the
    round-trip error is pure round-to-nearest: <= scale/2 per element."""
    rng = np.random.default_rng(seed)
    kvh = int(rng.integers(1, 4))
    x = jnp.asarray(rng.normal(0.0, float(rng.uniform(0.02, 4.0)),
                               (kvh, int(rng.integers(1, 9)),
                                int(rng.integers(1, 17)))), jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=(1, 2))
    scale = jnp.where(amax > 0, amax / MAG_MAX, 1.0)  # calibrate convention
    assert bool(jnp.all(scale > 0))
    err = jnp.abs(kv_dequantize(kv_quantize(x, scale), scale) - x)
    assert bool(jnp.all(err <= scale[:, None, None] / 2 + 1e-7))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10**6), st.integers(0, 9), st.integers(1, 10))
def test_span_scatter_preserves_payload_bits(seed, start, length):
    """The span writer (prefill / chunked-prefill path) lands quantized
    payloads bit-exactly — including spans starting mid-block."""
    bs, kvh, hd = 4, 2, 8
    w = -(-(start + length) // bs)
    table = jnp.arange(1, w + 1, dtype=jnp.int32)[None]
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (1, kvh, length, hd)), jnp.float32)
    scale = jnp.full((kvh,), float(np.max(np.abs(x)) or 1.0) / MAG_MAX)
    q = kv_quantize(x, scale)
    pool = _paged_write_span(jnp.zeros((1 + w, kvh, bs, hd), jnp.int8),
                             table, jnp.asarray([start], jnp.int32), q)
    for t in range(length):
        p = start + t
        np.testing.assert_array_equal(
            np.asarray(pool[int(table[0, p // bs]), :, p % bs, :]),
            np.asarray(q[0, :, t, :]))


def test_ring_wrap_token_writes_bitexact():
    """Decode writes through a sliding-window ring slot (pos % ring_len):
    after wrapping, every ring slot holds exactly the quantized bits of
    the *latest* token written there."""
    cfg = dataclasses.replace(get_arch("stablelm-1.6b").reduced(),
                              dtype="float32")
    bs, ring_w = 4, 2
    ring_len = ring_w * bs
    cache = init_paged_quant_cache(cfg, 1 + ring_w, bs,
                                   np.full(cfg.n_kv_heads, 0.05),
                                   np.full(cfg.n_kv_heads, 0.07))
    table = jnp.arange(1, ring_w + 1, dtype=jnp.int32)[None]
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    mk = lambda pos, c: jnp.full((1, kvh, 1, hd), 0.1 * (pos + 1) * c,
                                 jnp.float32)
    n_tok = ring_len + 5  # wraps the ring
    for pos in range(n_tok):
        cache = _paged_write_token(cache, table,
                                   jnp.asarray([pos % ring_len], jnp.int32),
                                   mk(pos, 1.0), mk(pos, -1.0))
    for slot in range(ring_len):
        latest = max(p for p in range(n_tok) if p % ring_len == slot)
        pb, off = int(table[0, slot // bs]), slot % bs
        np.testing.assert_array_equal(
            np.asarray(cache.k[pb, :, off, :]),
            np.asarray(kv_quantize(mk(latest, 1.0), cache.k_scale)[0, :, 0, :]))
        np.testing.assert_array_equal(
            np.asarray(cache.v[pb, :, off, :]),
            np.asarray(kv_quantize(mk(latest, -1.0), cache.v_scale)[0, :, 0, :]))


def test_quant_cache_state_shapes(calibrated):
    """init_decode_state under kv_quant builds QuantPagedKVCache leaves
    with int8 pools and per-head f32 scales."""
    model, _ = calibrated
    qmodel = dataclasses.replace(
        model, opts=dataclasses.replace(model.opts, kv_quant="int8"))
    states = qmodel.init_decode_state(1, 8, paged=(5, 4))
    leaves = [l for l in jax.tree.leaves(
        states, is_leaf=lambda x: isinstance(x, QuantPagedKVCache))
        if isinstance(l, QuantPagedKVCache)]
    assert leaves, "no quantized pools in the decode state"
    for c in leaves:
        assert c.k.dtype == jnp.int8 and c.v.dtype == jnp.int8
        assert c.k_scale.dtype == jnp.float32
        assert c.k_scale.shape[-1] == model.cfg.n_kv_heads
        assert bool(jnp.all(c.k_scale > 0)) and bool(jnp.all(c.v_scale > 0))
