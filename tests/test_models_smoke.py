"""Per-arch reduced-config smoke tests (assignment requirement).

Each assigned architecture instantiates a REDUCED config of the same family
and runs one forward/train step on CPU, asserting output shapes + no NaNs;
plus one decode step against a fresh serving state.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.models.model import Model, input_specs
from repro.models.transformer import ModelOptions
from repro.optim import AdamWConfig, adamw_init, adamw_update

ARCH_IDS = list(ARCHS)


def _batch_for(cfg, b, s, key):
    if cfg.n_codebooks:
        tokens = jax.random.randint(key, (b, cfg.n_codebooks, s), 0, cfg.vocab)
    else:
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, key):
    cfg = get_arch(arch).reduced()
    model = Model(cfg, ModelOptions())
    params = model.init(key)
    batch = _batch_for(cfg, 2, 32, key)
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"

    # one full train step (grads + AdamW) must stay finite
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in gleaves), arch
    opt = adamw_init(params)
    params2, opt2, stats = adamw_update(params, grads, opt, AdamWConfig())
    assert bool(jnp.isfinite(stats["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved, f"{arch}: AdamW produced no update"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch, key):
    cfg = get_arch(arch).reduced()
    model = Model(cfg, ModelOptions())
    params = model.init(key)
    b, max_len = 2, 64
    states = model.init_decode_state(b, max_len)
    tok = _batch_for(cfg, b, 1, key)["tokens"]
    logits, states2 = model.decode(params, tok, states, jnp.int32(0))
    v = cfg.vocab
    if cfg.n_codebooks:
        assert logits.shape == (b, 1, cfg.n_codebooks, v)
    else:
        assert logits.shape == (b, 1, v)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    # state tree structure preserved
    assert jax.tree_util.tree_structure(states) == jax.tree_util.tree_structure(states2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_arch(arch)
    for shape in SHAPES.values():
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            assert shape.name == "long_500k" and not cfg.is_subquadratic
            continue
        specs = input_specs(cfg, shape)
        if shape.kind in ("train", "prefill"):
            t = specs["tokens"]
            assert t.shape[0] == shape.global_batch and t.shape[-1] == shape.seq_len
        else:
            assert specs["token"].shape[-1] == 1
            assert specs["pos"].shape == ()
            # decode state trees must be non-empty and finite-sized
            leaves = jax.tree.leaves(specs["states"])
            assert leaves, arch


def test_long_500k_applicability_matrix():
    long = SHAPES["long_500k"]
    runnable = {a for a in ARCH_IDS if shape_applicable(get_arch(a), long)[0]}
    assert runnable == {"recurrentgemma-2b", "xlstm-125m"}


def test_param_counts_in_band():
    """Analytic param counts must be in the advertised ballpark."""
    bands = {
        "stablelm-1.6b": (1.2e9, 2.2e9),
        "qwen1.5-110b": (95e9, 125e9),
        "qwen1.5-0.5b": (0.3e9, 0.7e9),
        "qwen2.5-32b": (28e9, 36e9),
        "recurrentgemma-2b": (2e9, 3.5e9),
        "xlstm-125m": (0.08e9, 0.2e9),
        "musicgen-large": (1.5e9, 2.6e9),
        "llama-3.2-vision-90b": (75e9, 100e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "granite-moe-1b-a400m": (0.8e9, 1.6e9),
    }
    for arch, (lo, hi) in bands.items():
        n = get_arch(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"
    # MoE actives
    assert get_arch("qwen3-moe-30b-a3b").active_param_count() < 5e9
    assert get_arch("granite-moe-1b-a400m").active_param_count() < 0.6e9
