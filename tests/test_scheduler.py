"""Chunked-prefill scheduler correctness (docs/SERVING.md §Scheduling).

The load-bearing claims:

* chunked prefill is token-identical to one-shot (blocking) prefill on
  the dense and paged layouts, under exact numerics and under a
  PTQ-calibrated int8 plan — for any chunk budget (property test);
* decode never starves: while a long prompt prefills chunk by chunk,
  every engine round still advances the active decode slots;
* paged admission is exception-safe: a forced evict shortfall rolls back
  every incref, re-queues the request FCFS, and the engine recovers and
  serves it once blocks free up;
* the intake/outtake bugfixes: empty prompts are rejected with
  ``ValueError`` (not a strippable assert), ``prompt_len +
  max_new_tokens == max_len`` is accepted, outputs are drained to
  callers exactly once, and latency is measured from submission.
"""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import (
    SchedulerConfig, ServeConfig, ServeEngine, SlotState, TokenBudgetScheduler,
    pack_prompts,
)


def _model(arch, mode="exact", **red):
    cfg = get_arch(arch).reduced(**red)
    cfg = dataclasses.replace(cfg, dtype="float32")
    return Model(cfg, ModelOptions(cc=ComputeConfig(mode)))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    shape = (cfg.n_codebooks,) if cfg.n_codebooks else ()
    return [rng.integers(0, cfg.vocab, shape + (l,), dtype=np.int32) for l in lens]


def _serve(model, params, prompts, gen, chunk_tokens, **cfg_kw):
    eng = ServeEngine(model, params, ServeConfig(
        astra_accounting=False, prefill_chunk_tokens=chunk_tokens, **cfg_kw))
    return eng, eng.generate_batch(prompts, gen)


# ------------------------------------------------------------ scheduler unit
def test_request_timing_math():
    from repro.serve.accounting import request_timing

    ev = [(10.0, 1), (10.5, 4), (12.0, 4)]  # TTFT token + two fused chunks
    t = request_timing(t_submit=9.0, t_admit=9.2, t_first=10.0,
                       token_events=ev, t_done=12.1)
    assert t.queue_time_s == pytest.approx(0.2)
    assert t.ttft_s == pytest.approx(1.0)
    assert t.wall_time_s == pytest.approx(3.1)
    assert t.max_itl_s == pytest.approx(1.5)  # worst inter-event gap
    assert t.mean_itl_s == pytest.approx(2.0 / 8)  # span / (9 tokens - 1)
    z = request_timing(1.0, 1.0, 1.0, [], 1.0)
    assert z.mean_itl_s == z.max_itl_s == 0.0


def test_budget_split_fcfs():
    s = TokenBudgetScheduler(SchedulerConfig(token_budget=10))
    # decode claims one token per active slot; FCFS head is served first
    assert s.plan_chunks([(0, 20), (1, 5)], n_active_decode=2) == [(0, 8)]
    assert s.plan_chunks([(0, 3), (1, 5)], n_active_decode=2) == [(0, 3), (1, 5)]
    # decode saturates the budget: prefill waits, the round is counted
    assert s.plan_chunks([(0, 4)], n_active_decode=10) == []
    assert s.stats["starved_rounds"] == 1
    with pytest.raises(ValueError):
        SchedulerConfig(token_budget=0)


# ----------------------------------------------------------- token parity
@pytest.mark.parametrize("arch", ["stablelm-1.6b", "recurrentgemma-2b"])
@pytest.mark.parametrize("budget", [3, 64])
def test_chunked_matches_blocking_dense(arch, budget, key):
    """Dense windowed-scan chunks == one-shot prefill, any budget."""
    model = _model(arch, **({"window": 8} if get_arch(arch).window else {}))
    params = model.init(key)
    prompts = _prompts(model.cfg, (6, 11, 16))
    kw = dict(max_slots=3, max_len=32, chunk_steps=4)
    _, ref = _serve(model, params, prompts, 8, 0, **kw)
    eng, outs = _serve(model, params, prompts, 8, budget, **kw)
    assert eng.scheduler_stats["active"]
    for r, o in zip(ref, outs):
        np.testing.assert_array_equal(o.tokens, r.tokens)


@pytest.mark.parametrize("budget", [5, 64])
def test_chunked_matches_blocking_paged(budget, key):
    """Paged suffix chunks (non-block-aligned resume points) == one-shot."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    prompts = _prompts(model.cfg, (6, 11, 16))
    kw = dict(max_slots=3, max_len=32, chunk_steps=4, kv_block_size=8)
    _, ref = _serve(model, params, prompts, 8, 0, **kw)
    eng, outs = _serve(model, params, prompts, 8, budget, **kw)
    assert eng.scheduler_stats["active"]
    for r, o in zip(ref, outs):
        np.testing.assert_array_equal(o.tokens, r.tokens)


def test_chunked_matches_blocking_calibrated_int8(key):
    """Calibrated int8: static act scales make every chunk boundary
    invisible (dynamic scales would quantize each chunk differently)."""
    base = _model("stablelm-1.6b")
    params = base.init(key)
    prompts = _prompts(base.cfg, (7, 12))
    cal_tokens, _ = pack_prompts(prompts, base.cfg)
    model = Model(base.cfg, ModelOptions(plan="int8")).calibrate(
        params, {"tokens": cal_tokens})
    kw = dict(max_slots=2, max_len=32, chunk_steps=3, kv_block_size=8)
    _, ref = _serve(model, params, prompts, 6, 0, **kw)
    _, outs = _serve(model, params, prompts, 6, 4, **kw)
    for r, o in zip(ref, outs):
        np.testing.assert_array_equal(o.tokens, r.tokens)


def test_chunked_composes_with_prefix_cache(key):
    """A prefix-cache hit seeds ``filled``; the remaining chunks resume
    from it and outputs still match the blocking engine."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    [shared] = _prompts(model.cfg, (16,))
    ext = np.concatenate([shared, _prompts(model.cfg, (5,), seed=7)[0]])
    kw = dict(max_slots=2, max_len=32, chunk_steps=3, kv_block_size=4)
    ref_eng = ServeEngine(model, params, ServeConfig(astra_accounting=False, **kw))
    ref_eng.generate_batch([shared], 4)  # primes the tree
    ref = ref_eng.generate_batch([shared, ext], 6)
    eng = ServeEngine(model, params, ServeConfig(
        astra_accounting=False, prefill_chunk_tokens=3, **kw))
    eng.generate_batch([shared], 4)
    outs = eng.generate_batch([shared, ext], 6)
    assert eng.prefix_stats["hit_tokens"] > 0
    for r, o in zip(ref, outs):
        np.testing.assert_array_equal(o.tokens, r.tokens)
    # the hit shows up as zero-billed cached tokens once accounting is on
    assert outs[0].timing.ttft_s >= 0.0


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "musicgen-large"])
@pytest.mark.slow
def test_chunked_matches_blocking_archs(arch, key):
    """Long-running: chunked parity across MoE and multi-codebook stacks."""
    model = _model(arch)
    params = model.init(key)
    prompts = _prompts(model.cfg, (5, 9, 12))
    kw = dict(max_slots=2, max_len=32, chunk_steps=4)
    _, ref = _serve(model, params, prompts, 6, 0, **kw)
    _, outs = _serve(model, params, prompts, 6, 4, **kw)
    for r, o in zip(ref, outs):
        np.testing.assert_array_equal(o.tokens, r.tokens)


def test_paged_stateful_stack_falls_back_to_blocking(key):
    """Recurrent/windowed stacks can't resume state from pooled blocks:
    paged + chunked requests admit one-shot, correctly."""
    model = _model("recurrentgemma-2b", window=8)
    params = model.init(key)
    prompts = _prompts(model.cfg, (6, 9))
    kw = dict(max_slots=2, max_len=32, chunk_steps=4, kv_block_size=8)
    _, ref = _serve(model, params, prompts, 6, 0, **kw)
    eng, outs = _serve(model, params, prompts, 6, 5, **kw)
    assert not eng.scheduler_stats["active"]
    for r, o in zip(ref, outs):
        np.testing.assert_array_equal(o.tokens, r.tokens)


# ----------------------------------------------------- interleave fairness
def test_no_decode_starvation_while_long_prompt_prefills(key):
    """A long prompt admitted mid-decode must not stall the active slot:
    every round during its multi-chunk prefill still delivers decode
    tokens (this is the head-of-line-blocking fix, structurally)."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    [short] = _prompts(model.cfg, (4,))
    [long_p] = _prompts(model.cfg, (48,), seed=3)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=64, chunk_steps=2, kv_block_size=8,
        astra_accounting=False, prefill_chunk_tokens=8))
    outs = []
    eng.submit(short, 30)
    outs += eng.step()  # short admits, prefills (one chunk), starts decoding
    long_id = eng.submit(long_p, 2)

    def long_slot():
        for s in eng._slots:
            if s is not None and s.req.id == long_id:
                return s
        return None

    def short_tokens():
        for s in eng._slots:
            if s is not None and s.req.id != long_id:
                return sum(t.shape[-1] for t in s.generated)
        return None

    prefill_rounds = 0
    while True:
        before = short_tokens()
        outs += eng.step()
        slot = long_slot()
        if slot is None or slot.state is not SlotState.PREFILLING:
            break
        prefill_rounds += 1
        after = short_tokens()
        assert before is not None and after is not None
        assert after > before, "active decode slot starved during prefill"
    assert prefill_rounds >= 3  # the prompt really was chunked across rounds
    outs += eng.run()
    # parity against the blocking engine on the same interleaved schedule
    ref_eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=64, chunk_steps=2, kv_block_size=8,
        astra_accounting=False))
    ref_outs = []
    ref_eng.submit(short, 30)
    ref_outs += ref_eng.step()
    ref_eng.submit(long_p, 2)
    ref_outs += ref_eng.run()
    by_id = {o.request_id: o for o in outs}
    for r in ref_outs:
        np.testing.assert_array_equal(by_id[r.request_id].tokens, r.tokens)


# -------------------------------------------------------------- property
@functools.lru_cache(maxsize=1)
def _prop_setup():
    model = _model("stablelm-1.6b")
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 20), st.lists(st.integers(1, 14), min_size=1, max_size=3),
       st.integers(0, 1))
def test_random_budgets_token_identical(budget, lens, paged):
    """Any chunk budget x prompt mix x layout: chunked == blocking."""
    model, params = _prop_setup()
    prompts = _prompts(model.cfg, lens, seed=sum(lens) + budget)
    kw = dict(max_slots=2, max_len=24, chunk_steps=3,
              kv_block_size=4 if paged else 0)
    _, ref = _serve(model, params, prompts, 5, 0, **kw)
    _, outs = _serve(model, params, prompts, 5, budget, **kw)
    for r, o in zip(ref, outs):
        np.testing.assert_array_equal(o.tokens, r.tokens)


# ------------------------------------------------- admission exception safety
def _forced_shortfall_engine(key, chunked=False, **cfg_over):
    """Engine at the pool floor with an interned tree and a broken evict:
    the next admission's alloc must fail — and must fail *cleanly*."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    # floor = 1 + 2 slots * ceil(16/4) = 9 blocks: zero prefix headroom
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=16, chunk_steps=2, kv_block_size=4,
        kv_pool_blocks=9, astra_accounting=False,
        prefill_chunk_tokens=4 if chunked else 0, **cfg_over))
    for s in range(3):  # each interns 2 blocks -> 6 tree-held of 8 usable
        eng.generate_batch(_prompts(model.cfg, (8,), seed=10 + s), 4)
    assert eng.prefix_stats["interned_blocks"] == 6
    return model, params, eng


@pytest.mark.parametrize("chunked", [False, True], ids=["blocking", "chunked"])
def test_forced_evict_shortfall_rolls_back_and_recovers(chunked, key):
    model, params, eng = _forced_shortfall_engine(key, chunked)
    # keep one slot decoding so blocks stay held and the engine isn't idle
    busy_id = eng.submit(_prompts(model.cfg, (4,), seed=20)[0], 10)
    outs = eng.step()
    n_live0 = eng._pool.n_live
    real_evict = eng._prefix.evict
    eng._prefix.evict = lambda n, pool: 0  # forced shortfall
    blocked = _prompts(model.cfg, (8,), seed=21)[0]
    blocked_id = eng.submit(blocked, 4)
    outs += eng.step()  # admission fails cleanly; decode continues
    assert eng._pool.n_live == n_live0  # no leaked increfs
    assert [r.id for r in eng._queue] == [blocked_id]  # re-queued, FCFS
    free_rows = [i for i, s in enumerate(eng._slots) if s is None]
    assert all(not eng._tables_np[i].any() for i in free_rows)  # rows at scratch
    eng._prefix.evict = real_evict
    outs += eng.run()  # retries succeed once eviction works again
    by_id = {o.request_id: o for o in outs}
    assert busy_id in by_id and blocked_id in by_id
    ref = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=16, astra_accounting=False))
    [want] = ref.generate_batch([blocked], 4)
    np.testing.assert_array_equal(by_id[blocked_id].tokens, want.tokens)


def test_wedged_admission_raises_instead_of_spinning(key):
    """All slots free + admission failing forever can release nothing:
    with the degraded-mode ladder disabled the engine must raise, not
    spin (the ladder's shed level is the graceful alternative, below)."""
    model, params, eng = _forced_shortfall_engine(key, degraded_mode=False)
    eng._prefix.evict = lambda n, pool: 0
    eng.submit(_prompts(model.cfg, (8,), seed=22)[0], 4)
    with pytest.raises(RuntimeError, match="wedged"):
        eng.run()


def test_degraded_ladder_sheds_instead_of_wedging(key):
    """Same forced-shortfall scenario with the ladder on: the engine
    walks flush_prefix -> no_prefix_admission -> shed_load and fails the
    queued request as a terminal pool_pressure fault instead of raising
    (docs/SERVING.md §Fault tolerance)."""
    model, params, eng = _forced_shortfall_engine(key)
    eng._prefix.evict = lambda n, pool: 0
    rid = eng.submit(_prompts(model.cfg, (8,), seed=22)[0], 4)
    outs = eng.run()  # terminates: the shed level bounds the stall
    [out] = [o for o in outs if o.request_id == rid]
    assert out.fault_reason == "pool_pressure"
    assert out.gen_len == 0
    st = eng.stats()
    assert st["n_shed"] == 1
    assert [name for _, name in st["degraded_transitions"]] == [
        "flush_prefix", "no_prefix_admission", "shed_load"]
    assert eng.kv_stats["degraded_level"] == "shed_load"
    assert eng.kv_stats["prefix_admission"] is False


# ------------------------------------------------- intake/outtake bugfixes
def test_submit_rejects_empty_prompt_and_accepts_boundary(key):
    model = _model("stablelm-1.6b")
    params = model.init(key)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=16, astra_accounting=False))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros(0, np.int32), 4)
    with pytest.raises(ValueError, match="empty prompt"):
        pack_prompts([np.zeros(0, np.int32)], model.cfg)
    with pytest.raises(ValueError, match="at least one prompt"):
        pack_prompts([], model.cfg)
    # prompt_len + max_new_tokens == max_len is exactly representable
    [p] = _prompts(model.cfg, (6,))
    [out] = eng.generate_batch([p], 10)
    assert out.gen_len == 10
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(p, 11)


def test_outputs_drained_exactly_once(key):
    """A long-lived engine hands each output to run()/step() once and
    keeps no history (the unbounded-growth / re-return bug)."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=24, astra_accounting=False))
    prompts = _prompts(model.cfg, (5, 9, 7, 4))
    a = [eng.submit(p, 4) for p in prompts[:2]]
    first = eng.run()
    assert sorted(o.request_id for o in first) == a
    b = [eng.submit(p, 4) for p in prompts[2:]]
    b.append(eng.submit(prompts[0], 0))  # gen=0 completes at submit
    second = eng.run()
    assert sorted(o.request_id for o in second) == sorted(b)
    assert eng.run() == []  # nothing left; no historical re-returns
    assert not eng._outbox


def test_timing_measured_from_submission(key):
    """Queue wait is part of wall time: with one slot, the second request
    waits and its queue_time/ttft must reflect that (the t_start-in-admit
    bug reported zero queue wait)."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=24, chunk_steps=4, astra_accounting=False))
    prompts = _prompts(model.cfg, (6, 6))
    outs = eng.generate_batch(prompts, 8)
    t0, t1 = outs[0].timing, outs[1].timing
    for t in (t0, t1):
        assert 0.0 <= t.queue_time_s <= t.ttft_s <= t.wall_time_s
        assert t.max_itl_s >= t.mean_itl_s >= 0.0
    # the second request decoded only after the first retired
    assert t1.queue_time_s > t0.queue_time_s
    assert t1.queue_time_s > 0.0
    for o in outs:
        assert o.wall_time_s == o.timing.wall_time_s


def test_forced_evict_shortfall_quant_pool_rolls_back(key):
    """Admission rollback on the *quantized* pool: a forced evict
    shortfall must leave the int8 pool's refcounts (and the derived byte
    accounting) exactly where they were, then recover token-identically
    once eviction works again."""
    cfg = dataclasses.replace(get_arch("stablelm-1.6b").reduced(),
                              dtype="float32")
    model = Model(cfg, ModelOptions(plan="int8"))
    params = model.init(key)
    [cal] = _prompts(cfg, (8,), seed=9)
    model = model.calibrate(params, {"tokens": cal[None]})
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=16, chunk_steps=2, kv_block_size=4,
        kv_pool_blocks=9, kv_quant="int8", astra_accounting=False))
    for s in range(3):  # intern 6 of 8 usable blocks: zero headroom
        eng.generate_batch(_prompts(cfg, (8,), seed=10 + s), 4)
    assert eng.prefix_stats["interned_blocks"] == 6
    busy_id = eng.submit(_prompts(cfg, (4,), seed=20)[0], 10)
    outs = eng.step()
    n_live0 = eng._pool.n_live
    bytes0 = eng.kv_stats["live_bytes"]
    real_evict = eng._prefix.evict
    eng._prefix.evict = lambda n, pool: 0  # forced shortfall
    blocked = _prompts(cfg, (8,), seed=21)[0]
    blocked_id = eng.submit(blocked, 4)
    outs += eng.step()  # admission fails cleanly; decode continues
    assert eng._pool.n_live == n_live0  # no leaked increfs
    assert eng.kv_stats["live_bytes"] == bytes0  # accounting in sync
    assert [r.id for r in eng._queue] == [blocked_id]
    eng._prefix.evict = real_evict
    outs += eng.run()
    by_id = {o.request_id: o for o in outs}
    assert busy_id in by_id and blocked_id in by_id
    ref = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=16, kv_block_size=4, kv_quant="int8",
        astra_accounting=False))
    [want] = ref.generate_batch([blocked], 4)
    np.testing.assert_array_equal(by_id[blocked_id].tokens, want.tokens)
