"""Hardware model: mapping, energy, simulator, baselines — the paper's
quantitative claims as assertions.
"""
import pytest

from repro.configs import PAPER_MODELS, PAPER_SEQ_LEN, get_arch
from repro.core.baselines import BASELINES, compare_all, simulate_baseline
from repro.core.energy import AstraChipConfig
from repro.core.mapping import MatmulOp, map_matmul
from repro.core.photonics import PhotonicParams, vdpe_scalability_table
from repro.core.simulator import model_ops, simulate

CHIP = AstraChipConfig()


# ---------------------------------------------------------------- mapping
def test_map_matmul_latency_scales_with_work():
    small = map_matmul(CHIP, MatmulOp("s", 64, 512, 64))
    big = map_matmul(CHIP, MatmulOp("b", 128, 512, 128))
    assert big.latency_s >= small.latency_s * 3.5  # 4x outputs


def test_output_stationary_single_adc_per_output():
    op = MatmulOp("x", 32, 4096, 16)  # K=4096 -> 4 passes per output
    cost = map_matmul(CHIP, op)
    assert cost.adc_convs == 32 * 16  # one conversion per output, not per pass
    assert cost.passes == 32 * 16 * 4


def test_dynamic_operands_cost_no_extra_latency():
    """ASTRA streams both operands — a dynamic-weight GEMM (QK^T) maps at
    the same latency as a static-weight GEMM of equal size."""
    stat = map_matmul(CHIP, MatmulOp("w", 64, 1024, 64, dynamic_w=False))
    dyn = map_matmul(CHIP, MatmulOp("d", 64, 1024, 64, dynamic_w=True))
    assert dyn.latency_s == stat.latency_s
    # and strictly less HBM energy (no weight fetch)
    assert dyn.energy_j.get("hbm", 0.0) <= stat.energy_j.get("hbm", 0.0)


# ------------------------------------------------------------------ Fig. 4
def test_vdpe_scalability_monotone():
    rows = vdpe_scalability_table(PhotonicParams())
    lanes = [r["lanes"] for r in rows]
    laser = [r["laser_mw"] for r in rows]
    assert lanes == sorted(lanes) and laser == sorted(laser)
    by_lane = {r["lanes"]: r for r in rows}
    assert by_lane[1024]["laser_mw"] < 1000.0  # paper's 1024-OAG point feasible


def test_rx_power_is_papers_operating_point():
    assert PhotonicParams().rx_power_w == pytest.approx(0.5e-6)


# ------------------------------------------------------------------ Fig. 5
def test_energy_breakdown_serializers_and_oags_dominate():
    """Paper: 'serializers and OAGs dominate energy usage'."""
    cfg = get_arch("bert-base")
    rep = simulate(cfg, CHIP, seq=PAPER_SEQ_LEN[cfg.name])
    e = rep.energy_j
    # serialization machinery (fresh encode + replay registers + B-to-S) and
    # the OAG modulators — the paper's "serializers and OAGs"
    front = (e.get("serializer", 0) + e.get("replay", 0) + e.get("bts", 0)
             + e.get("oag_mod", 0))
    assert front > 0.4 * rep.total_energy_j
    # ADC limited to final outputs must NOT dominate
    assert e.get("adc", 0) < front


# ----------------------------------------------------------- Fig. 6 + §III
@pytest.mark.parametrize("model", list(PAPER_MODELS))
def test_speedup_claim_vs_best_accelerator(model):
    """>= 7.6x speedup vs the best non-ASTRA accelerator on every model."""
    cfg = get_arch(model)
    seq = PAPER_SEQ_LEN[cfg.name]
    astra = simulate(cfg, CHIP, seq=seq)
    accels = [
        simulate_baseline(spec, cfg, seq)
        for name, spec in BASELINES.items()
        if name not in ("cpu", "gpu", "tpu")
    ]
    best = min(a.latency_s for a in accels)
    assert best / astra.latency_s >= 7.6, f"{model}: speedup {best / astra.latency_s:.2f}"


@pytest.mark.parametrize("model", list(PAPER_MODELS))
def test_energy_claim_vs_accelerators_and_platforms(model):
    cfg = get_arch(model)
    seq = PAPER_SEQ_LEN[cfg.name]
    astra = simulate(cfg, CHIP, seq=seq)
    for name, spec in BASELINES.items():
        rep = simulate_baseline(spec, cfg, seq)
        ratio = rep.total_energy_j / astra.total_energy_j
        if name in ("cpu", "gpu", "tpu"):
            assert ratio > 1000.0, f"{model}@{name}: {ratio:.1f}x"
        else:
            assert ratio >= 1.3, f"{model}@{name}: {ratio:.2f}x"


def test_compare_all_returns_astra_first():
    cfg = get_arch("opt-350m")
    reports = compare_all(cfg, CHIP, seq=PAPER_SEQ_LEN[cfg.name])
    assert reports[0].name == cfg.name and len(reports) == 1 + len(BASELINES)


# ---------------------------------------------------------------- op graphs
def test_model_ops_macs_match_analytic_scale():
    """Sanity: simulator op graph MACs ~ param_count for seq*batch tokens
    (dense decoder: ~= N_params MACs per token, attention adds more)."""
    cfg = get_arch("bert-base")
    mm, _ = model_ops(cfg, seq=128, batch=1)
    macs = sum(op.macs for op in mm)
    approx = cfg.param_count() * 128
    assert 0.5 * approx < macs < 3.0 * approx


def test_moe_ops_use_active_params_only():
    cfg = get_arch("qwen3-moe-30b-a3b")
    mm, _ = model_ops(cfg, seq=64, batch=1)
    macs = sum(op.macs for op in mm)
    dense_equiv = cfg.param_count() * 64
    active_equiv = cfg.active_param_count() * 64
    assert macs < 0.5 * dense_equiv
    assert macs > 0.3 * active_equiv
