"""ASTRA execution modes inside real models (the paper's accuracy story).

The paper: 8-bit quantization + 128-bit streams keeps accuracy within 1.2%
of FP32.  Here: int8 (expectation) and sc (bit-true streams) modes of a
small trained-ish model must track the exact logits and preserve greedy
decisions; int8 must equal the analytic expectation of sc exactly when
stream pairing is deterministic-exact per-product.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig, astra_matmul, EXACT, INT8, SC
from repro.models.model import Model
from repro.models.transformer import ModelOptions


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_arch("qwen1.5-0.5b").reduced(), dtype="float32")
    model = Model(cfg, ModelOptions())
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    tokens = jax.random.randint(key, (2, 24), 0, cfg.vocab)
    return cfg, params, tokens


def _logits(cfg, params, tokens, cc):
    model = Model(cfg, ModelOptions(cc=cc))
    from repro.models.transformer import forward

    logits, _, _ = forward(params, tokens, cfg, model.opts)
    return np.asarray(logits, np.float32)


def test_int8_mode_tracks_exact(setup):
    cfg, params, tokens = setup
    lo = _logits(cfg, params, tokens, EXACT)
    li = _logits(cfg, params, tokens, INT8)
    rel = np.linalg.norm(li - lo) / np.linalg.norm(lo)
    assert rel < 0.15, rel
    # greedy decisions mostly preserved (the deployable-accuracy criterion)
    agree = (li.argmax(-1) == lo.argmax(-1)).mean()
    assert agree > 0.9, agree


def test_sc_mode_tracks_int8(setup):
    """Stream rounding adds <=1 LSB per product: sc stays near int8."""
    cfg, params, tokens = setup
    li = _logits(cfg, params, tokens, INT8)
    ls = _logits(cfg, params, tokens, SC)
    rel = np.linalg.norm(ls - li) / np.linalg.norm(li)
    assert rel < 0.10, rel


def test_astra_matmul_batch_shapes(rng):
    """The layer entry point must handle arbitrary leading dims."""
    x = jnp.asarray(rng.standard_normal((2, 3, 5, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    ref = np.asarray(x @ w)
    for cc in (INT8, SC):
        out = np.asarray(astra_matmul(x, w, cc), np.float32)
        assert out.shape == ref.shape
        rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
        assert rel < 0.05, (cc.mode, rel)


def test_pallas_and_jnp_paths_agree(rng):
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    for mode in ("int8", "sc"):
        a = astra_matmul(x, w, ComputeConfig(mode, use_pallas=False))
        b = astra_matmul(x, w, ComputeConfig(mode, use_pallas=True))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_lfsr_mode_noisier_but_close(rng):
    """Paper-faithful LFSR pairing vs our deterministic default."""
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    ref = np.asarray(x @ w)
    det = np.asarray(astra_matmul(x, w, SC))
    lfsr = np.asarray(astra_matmul(x, w, ComputeConfig("sc", x_gen="lfsr", w_gen="bresenham")))
    e_det = np.linalg.norm(det - ref) / np.linalg.norm(ref)
    e_lfsr = np.linalg.norm(lfsr - ref) / np.linalg.norm(ref)
    assert e_det <= e_lfsr + 1e-6
    assert e_lfsr < 0.12
