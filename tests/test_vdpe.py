"""VDPE: pass tiling, analog accumulation, noise/ADC model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ossm import sc_matmul_value
from repro.core.quant import quantize
from repro.core.vdpe import VDPEConfig, sc_matmul, sc_matmul_error
from repro.core import photonics


@pytest.fixture()
def operands(rng):
    x = jnp.asarray(rng.standard_normal((8, 96)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((96, 12)), jnp.float32)
    return quantize(x), quantize(w, axis=0), x @ w


def test_noiseless_matches_functional_model(operands):
    xq, wq, _ = operands
    got = sc_matmul(xq, wq, VDPEConfig(lanes=32, noisy=False))
    want = sc_matmul_value(xq, wq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("lanes", [8, 32, 96, 1024])
def test_pass_tiling_invariance(operands, lanes):
    """K-dim tiling across passes must not change the result (the PCA
    integrates partial sums exactly — output-stationary invariant)."""
    xq, wq, _ = operands
    base = sc_matmul(xq, wq, VDPEConfig(lanes=96, noisy=False))
    tiled = sc_matmul(xq, wq, VDPEConfig(lanes=lanes, noisy=False))
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(base), rtol=1e-6)


def test_accuracy_vs_exact(operands):
    xq, wq, exact = operands
    err = sc_matmul_error(xq, wq, VDPEConfig(lanes=1024), exact)
    assert err < 0.03


def test_noise_increases_error_but_bounded(operands):
    xq, wq, exact = operands
    clean = sc_matmul_error(xq, wq, VDPEConfig(noisy=False), exact)
    noisy = sc_matmul_error(
        xq, wq, VDPEConfig(noisy=True, adc_bits=8), exact, key=jax.random.PRNGKey(1)
    )
    assert noisy >= clean * 0.9
    assert noisy < 0.15  # still a usable operating point (paper Fig. 4)


def test_adc_resolution_matters(operands):
    xq, wq, exact = operands
    e8 = sc_matmul_error(xq, wq, VDPEConfig(noisy=True, adc_bits=8), exact, key=jax.random.PRNGKey(0))
    e4 = sc_matmul_error(xq, wq, VDPEConfig(noisy=True, adc_bits=4), exact, key=jax.random.PRNGKey(0))
    assert e4 > e8


def test_shot_noise_grows_with_lanes():
    p = photonics.PhotonicParams()
    assert photonics.shot_noise_sigma_bits(p, 1024) > photonics.shot_noise_sigma_bits(p, 64)


def test_paper_operating_point_1024_lanes():
    """Fig. 4 claim: >=1024 OAGs/wavelength at ~0.5uW/OAG is feasible —
    accumulated shot noise stays below the 8-bit output ADC's quantization
    step, so stochastic-analog accumulation, not noise, sets the precision."""
    p = photonics.PhotonicParams()
    sigma = photonics.shot_noise_sigma_bits(p, 1024)
    full_scale = 1024 * 128.0  # all lanes, all-ones streams, in bit-charges
    adc_lsb = full_scale / 2**8
    assert sigma < 0.5 * adc_lsb
    # and the laser budget stays in a sane per-wavelength envelope (< 1 W)
    assert photonics.laser_power_w(p, 1024) < 1.0
