"""Quantization unit + property tests (paper operand format: sign + 7-bit)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.quant import (
    MAG_MAX, Calibrator, fake_quant, int8_matmul_exact, quantize,
)


def test_range_and_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    qt = quantize(x)
    assert qt.q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(qt.q))) <= MAG_MAX  # -128 code never used
    err = jnp.max(jnp.abs(qt.dequantize() - x))
    assert float(err) <= float(qt.scale) * 0.5 + 1e-6  # half-LSB rounding


def test_per_channel_beats_per_tensor(rng):
    # one giant-scale column would wreck per-tensor quantization
    x = rng.standard_normal((128, 16)).astype(np.float32)
    x[:, 3] *= 100.0
    xj = jnp.asarray(x)
    e_tensor = jnp.abs(quantize(xj).dequantize() - xj).mean()
    e_chan = jnp.abs(quantize(xj, axis=0).dequantize() - xj).mean()
    assert float(e_chan) < float(e_tensor) / 5


def test_zero_input_safe():
    qt = quantize(jnp.zeros((4, 4)))
    assert float(jnp.abs(qt.dequantize()).max()) == 0.0
    assert np.isfinite(float(qt.scale))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
def test_property_dequant_error_bounded(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n,)) * 10 ** rng.uniform(-3, 3), jnp.float32)
    qt = quantize(x)
    # |deq - x| <= scale/2 everywhere (symmetric round-to-nearest)
    assert float(jnp.max(jnp.abs(qt.dequantize() - x))) <= float(qt.scale) * 0.5 + 1e-5


def test_fake_quant_straight_through_grad(key):
    x = jax.random.normal(key, (8, 8))
    g = jax.grad(lambda t: jnp.sum(fake_quant(t) ** 2))(x)
    # STE: gradient equals that of identity-through ~ 2*fake_quant(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * fake_quant(x)), rtol=1e-5)


def test_int8_matmul_exact_matches_fp(rng):
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    out = int8_matmul_exact(quantize(x), quantize(w, axis=0))
    rel = jnp.linalg.norm(out - x @ w) / jnp.linalg.norm(x @ w)
    assert float(rel) < 0.02  # 8-bit PTQ noise floor


def test_calibrator_converges(rng):
    state = Calibrator.init()
    for _ in range(50):
        state = Calibrator.observe(state, jnp.asarray(rng.standard_normal(256) * 3))
    scale = Calibrator.scale(state)
    # absmax of 256 N(0, 3^2) samples ~ 3.3*sigma ~ 10; scale ~ 10/127
    assert 6.0 / MAG_MAX < float(scale) < 14.0 / MAG_MAX
