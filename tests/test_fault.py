"""Fault tolerance: bit-exact recovery, straggler substitution, determinism."""
import numpy as np
import pytest

from repro.data import DataConfig, Prefetcher, SyntheticLMDataset
from repro.runtime import FaultInjector, SimulatedFault, run_with_restarts
from repro.checkpoint import CheckpointManager


# --------------------------------------------------------------------- data
def test_data_step_addressable_determinism():
    cfg = DataConfig(vocab=97, seq_len=64, global_batch=4, seed=11)
    a = SyntheticLMDataset(cfg)
    b = SyntheticLMDataset(cfg)
    for step in (0, 7, 123):
        np.testing.assert_array_equal(a.batch_at(step)["tokens"], b.batch_at(step)["tokens"])
    assert not np.array_equal(a.batch_at(0)["tokens"], a.batch_at(1)["tokens"])


def test_data_has_learnable_structure():
    """Markov+copy stream must have materially lower bigram entropy than
    uniform — otherwise the e2e training examples cannot show learning."""
    cfg = DataConfig(vocab=256, seq_len=512, global_batch=8, seed=0)
    toks = SyntheticLMDataset(cfg).batch_at(0)["tokens"]
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    # average successor diversity per state << vocab
    diversity = np.mean([len(set(v)) / max(len(v), 1) for v in pairs.values() if len(v) >= 4])
    assert diversity < 0.9


def test_prefetcher_straggler_substitution():
    cfg = DataConfig(vocab=50, seq_len=16, global_batch=2, seed=3)
    ds = SyntheticLMDataset(cfg)
    # step 2's producer straggles beyond the deadline
    pf = Prefetcher(ds, depth=1, timeout_s=0.3, delay_injector=lambda s: 1.0 if s == 2 else 0.0)
    pf.start()
    try:
        for step in range(4):
            batch = pf.get(step)
            np.testing.assert_array_equal(batch["tokens"], ds.batch_at(step)["tokens"])
    finally:
        pf.stop()
    assert 2 in pf.substituted_steps  # deadline fired, backup used


# ------------------------------------------------------------------ restarts
def _counter_harness(tmp_path, fail_at, n_steps=10, ckpt_every=3):
    """Tiny deterministic 'training': state = running sum of step data."""
    data = [float(i * i % 7) for i in range(n_steps)]
    injector = FaultInjector(fail_at)
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)

    def init_state():
        return {"acc": np.zeros(())}

    def step_fn(state, step):
        injector.check(step)
        acc = state["acc"] + data[step]
        return {"acc": acc}, {"acc": float(acc)}

    return run_with_restarts(
        init_state=init_state, step_fn=step_fn, n_steps=n_steps,
        ckpt_manager=mgr, ckpt_every=ckpt_every,
    )


def test_restart_trajectory_bit_exact(tmp_path):
    clean = _counter_harness(tmp_path / "clean", fail_at=())
    faulty = _counter_harness(tmp_path / "faulty", fail_at=(4, 8))
    assert faulty["restarts"] == 2
    assert faulty["state"]["acc"] == clean["state"]["acc"]
    # metrics at every step match the fault-free run exactly
    for step, m in clean["metrics"].items():
        assert faulty["metrics"][step] == m


def test_restart_without_checkpoint_restarts_from_scratch(tmp_path):
    res = _counter_harness(tmp_path, fail_at=(1,), n_steps=5, ckpt_every=0)
    assert res["restarts"] == 1
    assert res["state"]["acc"] == sum(float(i * i % 7) for i in range(5))


def test_max_restarts_enforced(tmp_path):
    def bad_step(state, step):
        raise SimulatedFault("always")

    with pytest.raises(RuntimeError, match="max_restarts"):
        run_with_restarts(
            init_state=dict, step_fn=bad_step, n_steps=3,
            ckpt_manager=None, max_restarts=2,
        )


def test_injector_fires_once():
    inj = FaultInjector([5])
    with pytest.raises(SimulatedFault):
        inj.check(5)
    inj.check(5)  # second time passes
    assert inj.fired == [5]
