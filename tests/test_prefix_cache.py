"""Paged KV cache + radix-tree prefix reuse in the serve engine.

The load-bearing claims (docs/SERVING.md):

* paging is invisible to outputs: a paged engine is token-identical to
  the dense engine on every arch family (global, windowed ring, MoE,
  codebooks/xattn), including fused-vs-unfused decode with block tables;
* prefix hits (full, partial, divergent) reproduce cold-prefill tokens
  exactly and are reported in the engine stats / hardware accounting;
* shared blocks survive divergence (copy-on-write at block granularity)
  and ref-counted LRU eviction under pool pressure never corrupts a
  live or re-admitted request.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.attention import BlockTables
from repro.models.model import Model
from repro.models.transformer import ModelOptions, suffix_forward
from repro.serve import (
    GREEDY, ServeConfig, ServeEngine, make_fused_decode, unfused_decode,
)


def _model(arch="stablelm-1.6b", **red):
    cfg = dataclasses.replace(get_arch(arch).reduced(**red), dtype="float32")
    return Model(cfg, ModelOptions())


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    shape = (cfg.n_codebooks,) if cfg.n_codebooks else ()
    return [rng.integers(0, cfg.vocab, shape + (l,), dtype=np.int32) for l in lens]


def _dense_oracle(model, params, prompts, gen, max_len, chunk=4):
    eng = ServeEngine(model, params,
                      ServeConfig(max_slots=len(prompts), max_len=max_len,
                                  chunk_steps=chunk, astra_accounting=False))
    return [o.tokens for o in eng.generate_batch(prompts, gen)]


@pytest.fixture(scope="module")
def stablelm():
    model = _model()
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# ------------------------------------------------------- paged == dense
def test_paged_matches_dense_mixed_lengths(stablelm):
    model, params = stablelm
    prompts = _prompts(model.cfg, (6, 11, 16))
    refs = _dense_oracle(model, params, prompts, 8, 32)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=3, max_len=32, chunk_steps=4, kv_block_size=8))
    outs = eng.generate_batch(prompts, 8)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o.tokens, r)


@pytest.mark.parametrize("red,max_len", [({"window": 8}, 24), ({}, 20)],
                         ids=["ring", "window>max_len"])
def test_paged_windowed_matches_dense(red, max_len, key):
    """Sliding-window ring through block tables (incl. the scan-prefill
    regime where the window exceeds the pre-allocated max_len)."""
    model = _model("recurrentgemma-2b", **red)
    params = model.init(key)
    prompts = _prompts(model.cfg, (5, 9))
    refs = _dense_oracle(model, params, prompts, 6, max_len, chunk=3)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=max_len, chunk_steps=3, kv_block_size=4))
    assert not eng._suffix_path and eng._prefix is None  # recurrent: no reuse
    for o, r in zip(eng.generate_batch(prompts, 6), refs):
        np.testing.assert_array_equal(o.tokens, r)


def test_fused_matches_unfused_with_tables(stablelm):
    """The scan-fused decode and the per-dispatch loop agree through the
    block-table indirection (non-block-aligned max_len on purpose)."""
    model, params = stablelm
    b, bs, max_len = 2, 8, 20
    w = -(-max_len // bs)
    states = model.init_decode_state(b, max_len, paged=(1 + b * w, bs))
    table = jnp.asarray([[1 + i * w + j for j in range(w)] for i in range(b)], jnp.int32)
    tables = BlockTables(table, jnp.int32(0))
    key = jax.random.PRNGKey(1)
    tok = jax.random.randint(key, (b, 1), 0, model.cfg.vocab, jnp.int32)
    pos = jnp.full((b,), 5, jnp.int32)
    fused = make_fused_decode(model)
    tf, _, _ = fused(params, tok, states, pos, key, steps=6, sampler=GREEDY, tables=tables)
    tu, _, _ = unfused_decode(model, params, tok, states, pos, key, 6, GREEDY, tables=tables)
    np.testing.assert_array_equal(np.asarray(tf), np.asarray(tu))


# ------------------------------------------------------------ prefix hits
def test_prefix_hit_and_partial_hit_match_cold(stablelm):
    model, params = stablelm
    rng = np.random.default_rng(3)
    shared = rng.integers(0, model.cfg.vocab, 16, dtype=np.int32)
    extended = np.concatenate([shared, rng.integers(0, model.cfg.vocab, 5, dtype=np.int32)])
    [ref_full], [ref_ext] = (_dense_oracle(model, params, [p], 6, 48)
                             for p in (shared, extended))
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=48, chunk_steps=4, kv_block_size=8))
    [cold] = eng.generate_batch([shared], 6)
    assert eng.prefix_stats["hit_tokens"] == 0
    [hit] = eng.generate_batch([shared], 6)  # capped full hit (8 of 16)
    [part] = eng.generate_batch([extended], 6)  # partial hit (16 of 21)
    np.testing.assert_array_equal(cold.tokens, ref_full)
    np.testing.assert_array_equal(hit.tokens, ref_full)
    np.testing.assert_array_equal(part.tokens, ref_ext)
    stats = eng.prefix_stats
    assert stats["hits"] == 2 and stats["hit_tokens"] == 8 + 16
    # prefix-hit tokens are billed at zero modeled ASTRA cost
    assert hit.hardware.cached_prompt_tokens == 8
    assert part.hardware.cached_prompt_tokens == 16
    assert cold.hardware.cached_prompt_tokens == 0
    assert hit.hardware.energy_j < cold.hardware.energy_j


def test_divergence_is_copy_on_write(stablelm):
    """Two requests sharing a block-aligned prefix then diverging must
    each match their cold run, and the interned prefix must survive."""
    model, params = stablelm
    rng = np.random.default_rng(4)
    shared = rng.integers(0, model.cfg.vocab, 16, dtype=np.int32)
    div_a = np.concatenate([shared, rng.integers(0, model.cfg.vocab, 7, dtype=np.int32)])
    div_b = np.concatenate([shared, rng.integers(0, model.cfg.vocab, 5, dtype=np.int32)])
    refs = {k: _dense_oracle(model, params, [p], 6, 48)[0]
            for k, p in (("s", shared), ("a", div_a), ("b", div_b))}
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=48, chunk_steps=4, kv_block_size=8))
    eng.generate_batch([shared], 4)  # prime: interns the shared blocks
    outs = eng.generate_batch([div_a, div_b], 6)  # batched divergent hits
    np.testing.assert_array_equal(outs[0].tokens, refs["a"])
    np.testing.assert_array_equal(outs[1].tokens, refs["b"])
    # the sharers wrote only private blocks: a re-hit still matches cold
    [again] = eng.generate_batch([shared], 6)
    np.testing.assert_array_equal(again.tokens, refs["s"])


def test_eviction_under_pool_pressure(stablelm):
    """Floor-sized pool (zero cache headroom): every admit must evict
    interned blocks, and outputs stay token-identical throughout."""
    model, params = stablelm
    rng = np.random.default_rng(5)
    floor = 1 + 2 * (48 // 8)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=48, chunk_steps=4, kv_block_size=8,
        kv_pool_blocks=floor))
    for trial in range(7):
        p = rng.integers(0, model.cfg.vocab, 17 + trial, dtype=np.int32)
        [o] = eng.generate_batch([p], 5)
        [ref] = _dense_oracle(model, params, [p], 5, 48)
        np.testing.assert_array_equal(o.tokens, ref)
    assert eng.prefix_stats["evictions"] > 0
    # no leak: with both slots idle, live blocks are all tree-interned
    assert eng._pool.n_live == eng.prefix_stats["interned_blocks"]


def test_moe_arch_takes_suffix_path(key):
    """Pure-attention MoE stacks are prefix-cache eligible (drop-free)."""
    model = _model("granite-moe-1b-a400m")
    params = model.init(key)
    [p] = _prompts(model.cfg, (14,), seed=6)
    [ref] = _dense_oracle(model, params, [p], 4, 24)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=24, chunk_steps=4, kv_block_size=4))
    assert eng._suffix_path and eng._prefix is not None
    [cold] = eng.generate_batch([p], 4)
    [hit] = eng.generate_batch([p], 4)
    np.testing.assert_array_equal(cold.tokens, ref)
    np.testing.assert_array_equal(hit.tokens, ref)
    assert eng.prefix_stats["hit_tokens"] > 0


def test_prefix_reuse_requires_deterministic_kv(stablelm):
    """Uncalibrated dynamic-scale plans (int8/sc) must auto-disable
    reuse: their per-tensor act scales depend on batch packing, so
    replayed KV would make outputs admission-history-dependent.
    Calibration (static per-site scales) re-enables it."""
    model, params = stablelm
    cfg = ServeConfig(max_slots=1, max_len=32, kv_block_size=8)
    assert ServeEngine(model, params, cfg)._prefix is not None  # exact: on
    int8 = model.with_plan("int8")
    assert ServeEngine(int8, params, cfg)._prefix is None  # dynamic scales: off
    [p] = _prompts(model.cfg, (10,), seed=8)
    calibrated = int8.calibrate(params, {"tokens": p[None]})
    assert ServeEngine(calibrated, params, cfg)._prefix is not None


# ---------------------------------------------------------------- edges
def test_gen_len_zero_with_prefix_cache(stablelm):
    model, params = stablelm
    [p] = _prompts(model.cfg, (12,), seed=7)
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=32, kv_block_size=8))
    [out] = eng.generate_batch([p], 0)
    assert out.gen_len == 0
    assert eng._pool.n_live == 0  # never took blocks


def test_pool_capacity_validated_at_construction(stablelm):
    model, params = stablelm
    with pytest.raises(ValueError, match="kv_pool_blocks"):
        ServeEngine(model, params, ServeConfig(
            max_slots=2, max_len=32, kv_block_size=8, kv_pool_blocks=6))


def test_suffix_forward_rejects_stateful_stacks(key):
    model = _model("recurrentgemma-2b", window=8)
    params = model.init(key)
    states = model.init_decode_state(1, 16, paged=(9, 4))
    with pytest.raises(ValueError, match="pure global-attention"):
        suffix_forward(params, jnp.zeros((1, 4), jnp.int32), model.cfg,
                       model.opts, states, jnp.zeros((1, 4), jnp.int32),
                       jnp.zeros((1,), jnp.int32), 4)


# ----------------------------------------------------- quantized int8 pool
def _quant_payloads(eng, ids):
    """int8 K/V payloads at physical block ids, across every pool leaf."""
    from repro.models.attention import PagedKVCache, QuantPagedKVCache
    leaves = [l for l in jax.tree.leaves(
        eng._states,
        is_leaf=lambda x: isinstance(x, (PagedKVCache, QuantPagedKVCache)))
        if isinstance(l, QuantPagedKVCache)]
    assert leaves, "engine holds no quantized pools"
    ids = jnp.asarray(ids, jnp.int32)
    out = []
    for c in leaves:
        ax = 1 if c.k.ndim == 5 else 0  # scan-unit pools carry [U, ...]
        out.append(np.asarray(jnp.take(c.k, ids, axis=ax)))
        out.append(np.asarray(jnp.take(c.v, ids, axis=ax)))
    return out


def test_quantized_hit_blocks_byte_identical_any_admission_order(stablelm):
    """Prefix hits on the int8 pool return byte-identical cached block
    payloads regardless of admission order, and divergence (COW) never
    rewrites the shared quantized prefix: with static calibrated scales,
    pooled KV is a pure function of the token path."""
    model, params = stablelm
    [cal] = _prompts(model.cfg, (12,), seed=31)
    qmodel = model.with_plan("int8").calibrate(params, {"tokens": cal[None]})
    rng = np.random.default_rng(32)
    shared = rng.integers(0, model.cfg.vocab, 8, dtype=np.int32)
    a, b = (np.concatenate(
        [shared, rng.integers(0, model.cfg.vocab, 4, dtype=np.int32)])
        for _ in range(2))

    def run(order):
        eng = ServeEngine(qmodel, params, ServeConfig(
            max_slots=1, max_len=24, kv_block_size=4, kv_quant="int8",
            astra_accounting=False))
        outs = [eng.generate_batch([p], 4)[0].tokens for p in order]
        return eng, outs

    e1, (a1, b1) = run([a, b])
    e2, (b2, a2) = run([b, a])
    assert e1.prefix_stats["hits"] > 0 and e2.prefix_stats["hits"] > 0
    np.testing.assert_array_equal(a1, a2)  # admission-order independent
    np.testing.assert_array_equal(b1, b2)
    for p in (a, b):
        ids1, ids2 = e1._prefix.match(p, 6), e2._prefix.match(p, 6)
        assert len(ids1) == len(ids2) > 0
        for x, y in zip(_quant_payloads(e1, ids1), _quant_payloads(e2, ids2)):
            np.testing.assert_array_equal(x, y)
