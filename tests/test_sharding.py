"""Sharding rule table resolved against the production mesh (abstractly —
tests run on 1 CPU device; AbstractMesh carries only the axis geometry).
"""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh

from repro.configs import get_arch
from repro.models.model import Model, input_specs
from repro.models.transformer import ModelOptions
from repro.configs.base import SHAPES
from repro.parallel.sharding import batch_specs, param_specs, state_specs

def _mesh(*pairs):
    """AbstractMesh across jax versions: <=0.5 takes ((name, size), ...)
    pairs; newer jax takes (axis_sizes, axis_names)."""
    try:
        return AbstractMesh(tuple(pairs))
    except TypeError:
        return AbstractMesh(tuple(s for _, s in pairs), tuple(n for n, _ in pairs))


MESH = _mesh(("data", 16), ("model", 16))
MESH3 = _mesh(("pod", 2), ("data", 16), ("model", 16))


def _spec_of(sharding):
    return tuple(sharding.spec)


def _flat(tree):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "qwen3-moe-30b-a3b", "recurrentgemma-2b", "xlstm-125m"])
def test_param_specs_divisible(arch):
    """Every sharded dim must be divisible by its mesh axes — the _guard
    contract; violations would fail at jit time on the pod."""
    cfg = get_arch(arch)
    shapes = Model(cfg, ModelOptions()).param_shapes()
    specs = param_specs(shapes, MESH)
    n_sharded = 0
    for (path, leaf), (_, sh) in zip(_flat(shapes), _flat(specs)):
        spec = _spec_of(sh)
        for dim, entry in zip(leaf.shape[-len(spec):] if spec else (), spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([MESH.shape[a] for a in axes]))
            assert dim % size == 0, (path, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 0, f"{arch}: no parameter is sharded at all"


def test_qwen110b_fits_per_device_budget():
    """FSDP+TP must bring the fp32 train state under the v5e HBM budget."""
    cfg = get_arch("qwen1.5-110b")
    shapes = Model(cfg, ModelOptions()).param_shapes()
    specs = param_specs(shapes, MESH)
    per_dev = 0
    for (_, leaf), (_, sh) in zip(_flat(shapes), _flat(specs)):
        n_shards = 1
        for dim, entry in zip(leaf.shape, (None,) * (len(leaf.shape) - len(_spec_of(sh))) + _spec_of(sh)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n_shards *= int(np.prod([MESH.shape[a] for a in axes]))
        per_dev += int(np.prod(leaf.shape)) // n_shards
    # params + grads + adam m/v in fp32 = 16 bytes per param-element
    assert per_dev * 16 < 16e9, f"{per_dev * 16 / 1e9:.1f} GB/device"


def test_batch_specs_use_all_dp_axes():
    cfg = get_arch("qwen1.5-0.5b")
    specs = input_specs(cfg, SHAPES["train_4k"])
    b2 = batch_specs(specs, MESH)
    assert _spec_of(b2["tokens"])[0] in (("data",), "data")
    b3 = batch_specs(specs, MESH3)
    assert _spec_of(b3["tokens"])[0] == ("pod", "data")


def test_batch_1_replicates():
    cfg = get_arch("recurrentgemma-2b")
    specs = input_specs(cfg, SHAPES["long_500k"])
    sh = batch_specs({"token": specs["token"]}, MESH)["token"]
    assert all(e is None for e in _spec_of(sh))  # batch 1: nothing to shard


def test_state_specs_kv_cache_layout():
    cfg = get_arch("qwen2.5-32b")  # kv=8: heads don't divide model=16
    specs = input_specs(cfg, SHAPES["decode_32k"])
    s_sh = state_specs(specs["states"], MESH, SHAPES["decode_32k"].global_batch)
    flat = _flat(s_sh)
    assert flat, "no decode state"
    for path, sh in flat:
        spec = _spec_of(sh)
        # batch axis sharded over data wherever present
        if len(spec) >= 2 and spec[0] is not None:
            assert spec[0] == ("data",) or spec[0] == "data"


def test_moe_expert_dim_sharded():
    cfg = get_arch("qwen3-moe-30b-a3b")
    shapes = Model(cfg, ModelOptions()).param_shapes()
    specs = param_specs(shapes, MESH)
    hits = [
        (_path(p), _spec_of(sh))
        for (p, leaf), (_, sh) in zip(_flat(shapes), _flat(specs))
        if "w_up" in _path(p) and "mlp" in _path(p)
    ]
    assert hits
    for path, spec in hits:
        assert "model" in str(spec), (path, spec)  # experts on the model axis


def _path(path):
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
