"""Optimizer + gradient compression."""
import jax
import jax.numpy as jnp

from repro.optim import (
    AdamWConfig, CompressorState, adamw_init, adamw_update, compress_init,
    compressed_psum, cosine_schedule,
)


def test_adamw_converges_quadratic(key):
    target = jax.random.normal(key, (16,))
    params = {"x": jnp.zeros((16,))}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum((p["x"] - target) ** 2))(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["x"] - target))) < 0.05


def test_adamw_grad_clipping():
    params = {"x": jnp.zeros((4,))}
    state = adamw_init(params)
    huge = {"x": jnp.full((4,), 1e6)}
    _, _, stats = adamw_update(params, huge, state, AdamWConfig(clip_norm=1.0))
    assert float(stats["grad_norm"]) > 1e5  # reported pre-clip


def test_weight_decay_shrinks_params(key):
    params = {"x": jax.random.normal(key, (8,)) * 10}
    state = adamw_init(params)
    zero_g = {"x": jnp.zeros((8,))}
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.5)
    p2, _, _ = adamw_update(params, zero_g, state, cfg)
    assert float(jnp.linalg.norm(p2["x"])) < float(jnp.linalg.norm(params["x"]))


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, warmup=10, total=100)) == 0.0
    assert abs(float(cosine_schedule(10, warmup=10, total=100)) - 1.0) < 1e-6
    end = float(cosine_schedule(100, warmup=10, total=100))
    assert abs(end - 0.1) < 1e-6  # min_ratio floor
    # monotone decay after warmup
    vals = [float(cosine_schedule(s, 10, 100)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


# ----------------------------------------------------------- compression
def _psum_sim(fn, *trees, axis="pod", n=2):
    """Simulate an n-member pod axis with vmap(axis_name=...)."""
    return jax.vmap(fn, axis_name=axis)(*trees)


def test_compressed_psum_approximates_mean_reduce(key):
    n = 2
    g = jax.random.normal(key, (n, 64))  # per-pod gradients
    state = compress_init({"w": g[0]})
    states = jax.tree.map(lambda r: jnp.stack([r] * n), state.residual)

    def body(g_leaf, r_leaf):
        out, st = compressed_psum({"w": g_leaf}, "pod", CompressorState({"w": r_leaf}))
        return out["w"], st.residual["w"]

    out, _ = _psum_sim(body, g, states["w"])
    want = jnp.mean(g, axis=0)  # compressed_psum averages (psum/n)
    rel = float(jnp.linalg.norm(out[0] - want) / jnp.linalg.norm(want))
    assert rel < 0.02  # int8 quantization noise


def test_error_feedback_cancels_bias(key):
    """Over repeated steps with a CONSTANT gradient, EF compression's
    cumulative average converges to the true mean reduce (bias -> 0)."""
    g0 = jax.random.normal(key, (64,)) * 1e-3  # small grads stress quantizer
    g1 = -g0 * 0.5
    g = jnp.stack([g0, g1])
    true_mean = jnp.mean(g, axis=0)

    def body(g_leaf):
        st = CompressorState({"w": jnp.zeros_like(g_leaf)})
        acc = jnp.zeros_like(g_leaf)
        outs = []
        for _ in range(30):
            out, st = compressed_psum({"w": g_leaf}, "pod", st)
            acc = acc + out["w"]
            outs.append(out["w"])
        return acc / 30

    avg = _psum_sim(body, g)[0]
    rel = float(jnp.linalg.norm(avg - true_mean) / jnp.linalg.norm(true_mean))
    assert rel < 0.01


def test_compression_ratio():
    """int8 payload is 4x smaller than fp32 — the DCN bytes the multi-pod
    all-reduce saves (per-leaf scalar scale is negligible)."""
    leaf = jnp.zeros((1024,), jnp.float32)
    assert leaf.nbytes / jnp.zeros((1024,), jnp.int8).nbytes == 4.0
