"""OSSM multiplier laws.

The paper's claim chain rests on: AND of decorrelated streams estimates the
product; the deterministic thermometer x bresenham pairing makes the
popcount equal round(m_x*m_w/128) to within 1 LSB (this is what lets 8-bit
+ 128-bit streams stay within 1.2% of FP32); LFSR pairing is the classic
noisy estimator with known bias-free mean.
"""
import itertools

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.ossm import ossm_expected, ossm_multiply, sc_dot, sc_matmul_value
from repro.core.quant import STREAM_LEN, quantize


def test_deterministic_pairing_within_1lsb_exhaustive():
    """|popcount - m_x*m_w/128| <= 1 for ALL 128x128 magnitude pairs."""
    mx = jnp.arange(128, dtype=jnp.int8)[:, None]  # broadcast grid
    mw = jnp.arange(128, dtype=jnp.int8)[None, :]
    got = np.asarray(ossm_multiply(mx, mw, "thermometer", "bresenham"), np.float64)
    want = np.asarray(mx, np.float64) * np.asarray(mw, np.float64) / STREAM_LEN
    assert np.abs(got - want).max() <= 1.0 + 1e-9


def test_sign_steering_all_quadrants():
    for sx, sw in itertools.product((-1, 1), repeat=2):
        qx = jnp.asarray([sx * 50], jnp.int8)
        qw = jnp.asarray([sw * 40], jnp.int8)
        got = int(ossm_multiply(qx, qw)[0])
        assert np.sign(got) == sx * sw or got == 0
        assert abs(got - sx * sw * 50 * 40 / 128) <= 1.0


def test_lfsr_pairing_bounded_error():
    """LFSR-vs-bresenham pairing: stochastic but bounded; mean error small."""
    mx = jnp.arange(128, dtype=jnp.int8)[:, None]
    mw = jnp.arange(128, dtype=jnp.int8)[None, :]
    got = np.asarray(ossm_multiply(mx, mw, "lfsr", "bresenham"), np.float64)
    want = np.asarray(mx, np.float64) * np.asarray(mw, np.float64) / STREAM_LEN
    err = np.abs(got - want)
    assert err.mean() < 2.0  # popcount units; classic SC noise level
    assert err.max() < 16.0


def test_zero_absorbing():
    z = jnp.zeros((1,), jnp.int8)
    anyv = jnp.asarray([127], jnp.int8)
    assert int(ossm_multiply(z, anyv)[0]) == 0
    assert int(ossm_multiply(anyv, z)[0]) == 0


def test_full_scale():
    m = jnp.asarray([127], jnp.int8)
    # 127*127/128 = 126.0078 -> within 1
    assert abs(int(ossm_multiply(m, m)[0]) - 126) <= 1


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-127, 127), min_size=1, max_size=64))
def test_property_dot_linearity(vals):
    """sc_dot == sum of elementwise ossm products (analog accumulation is
    exact integer addition — accumulation adds NO error)."""
    qx = jnp.asarray(vals, jnp.int8)
    qw = jnp.asarray(vals[::-1], jnp.int8)
    per_lane = ossm_multiply(qx, qw)
    assert int(sc_dot(qx, qw)) == int(per_lane.sum())


def test_sc_matmul_value_accuracy(rng):
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    out = sc_matmul_value(quantize(x), quantize(w, axis=0))
    ref = x @ w
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.03  # quant noise + <=1 LSB stream rounding


def test_ossm_expected_is_plain_product(rng):
    q = jnp.asarray(rng.integers(-127, 128, (10,)), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(ossm_expected(q, q)), np.asarray(q, np.int32) ** 2
    )
