"""Checkpoint subsystem: atomic roundtrip, bf16 views, retention, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint, save_checkpoint


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "params": {
            "w": jax.random.normal(k1, (8, 16), jnp.bfloat16),
            "b": jnp.zeros((16,), jnp.float32),
        },
        "opt": {"m": jax.random.normal(k2, (8, 16)), "step": jnp.int32(7)},
    }


def _assert_tree_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip_bf16(tmp_path, key):
    tree = _tree(key)
    save_checkpoint(str(tmp_path), 3, tree, metadata={"note": "hi"})
    restored, meta = restore_checkpoint(str(tmp_path), 3, tree)
    _assert_tree_equal(tree, restored)
    assert meta == {"note": "hi"}


def test_latest_step_ignores_tmp(tmp_path, key):
    tree = _tree(key)
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 5, tree)
    os.makedirs(tmp_path / "step_00000009.tmp")  # simulated crashed write
    assert latest_step(str(tmp_path)) == 5


def test_manager_async_and_retention(tmp_path, key):
    tree = _tree(key)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (0, 1, 2, 3):
        mgr.save(s, tree)
    mgr.wait()
    assert mgr.latest_step() == 3
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(kept) == 2  # retention pruned 0 and 1
    restored, _ = mgr.restore(3, tree)
    _assert_tree_equal(tree, restored)


def test_restore_with_shardings(tmp_path, key):
    """Elastic path: restore device_puts with explicit (1-device) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = _tree(key)
    save_checkpoint(str(tmp_path), 0, tree)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = restore_checkpoint(str(tmp_path), 0, tree, shardings=shardings)
    _assert_tree_equal(tree, restored)
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf.sharding, NamedSharding)


def test_elastic_restore_resolves_rules(tmp_path, key):
    """elastic_restore re-resolves the rule table against the new mesh."""
    from repro.runtime import elastic_restore

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"head": {"w": jax.random.normal(key, (16, 32))}}
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(0, tree)
    restored, _ = elastic_restore(mgr, 0, tree, mesh)
    _assert_tree_equal(tree, restored)
