"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the 1 real CPU
device; only launch/dryrun.py (a separate process) forces 512 placeholders."""
import os
import sys

# make `import repro` work without installation when run as `pytest tests/`
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _jax_x64_off():
    jax.config.update("jax_enable_x64", False)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
