"""``launch.serve.generate`` edge cases kept by the engine refactor.

The refactor replaced the per-step dispatch loop with packed prefill +
fused scan; these pin the behaviors the old driver guaranteed:

* ``gen_len=0`` returns the prompts untouched;
* multi-codebook (MusicGen) token grids keep their [B, C, S] shape through
  prefill, sampling, and feed-back;
* output layout is prompt ++ generated along the last axis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.serve import generate
from repro.models.model import Model
from repro.models.transformer import ModelOptions


@pytest.fixture(scope="module")
def stablelm():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = Model(cfg, ModelOptions())
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def musicgen():
    cfg = get_arch("musicgen-large").reduced()
    model = Model(cfg, ModelOptions())
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_gen_len_zero_returns_prompts(stablelm, key):
    model, params = stablelm
    prompts = jax.random.randint(key, (2, 5), 0, model.cfg.vocab, jnp.int32)
    toks, tps = generate(model, params, prompts, gen_len=0, max_len=16)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(prompts))
    assert tps == 0.0


def test_gen_len_one_single_prefill_token(stablelm, key):
    model, params = stablelm
    prompts = jax.random.randint(key, (2, 5), 0, model.cfg.vocab, jnp.int32)
    toks, _ = generate(model, params, prompts, gen_len=1, max_len=16)
    assert toks.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(toks[:, :5]), np.asarray(prompts))


def test_codebook_token_shapes(musicgen, key):
    model, params = musicgen
    cfg = model.cfg
    b, s0, gen = 2, 4, 5
    prompts = jax.random.randint(key, (b, cfg.n_codebooks, s0), 0, cfg.vocab, jnp.int32)
    toks, _ = generate(model, params, prompts, gen_len=gen, max_len=s0 + gen + 1)
    assert toks.shape == (b, cfg.n_codebooks, s0 + gen)
    np.testing.assert_array_equal(np.asarray(toks[..., :s0]), np.asarray(prompts))
    assert int(jnp.min(toks)) >= 0 and int(jnp.max(toks)) < cfg.vocab


def test_codebook_gen_len_zero(musicgen, key):
    model, params = musicgen
    cfg = model.cfg
    prompts = jax.random.randint(key, (1, cfg.n_codebooks, 3), 0, cfg.vocab, jnp.int32)
    toks, _ = generate(model, params, prompts, gen_len=0, max_len=8)
    assert toks.shape == (1, cfg.n_codebooks, 3)


def test_generate_matches_engine_greedy(stablelm, key):
    """The thin generate() wrapper and the engine agree token-for-token."""
    import dataclasses

    from repro.serve import ServeConfig, ServeEngine

    cfg = dataclasses.replace(get_arch("stablelm-1.6b").reduced(), dtype="float32")
    model = Model(cfg, ModelOptions())
    params = model.init(key)
    prompts = jax.random.randint(key, (3, 6), 0, cfg.vocab, jnp.int32)
    toks, _ = generate(model, params, prompts, gen_len=7, max_len=20)
    eng = ServeEngine(model, params, ServeConfig(max_slots=3, max_len=20))
    outs = eng.generate_batch([np.asarray(p) for p in prompts], 7)
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(toks[i, 6:]), o.tokens)
