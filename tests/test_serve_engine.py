"""Continuous-batching serve engine correctness.

The load-bearing claims:

* mixed prompt lengths in ONE running batch reproduce per-request decoding
  exactly (greedy), on both prefill strategies (packed full-seq for pure
  attention stacks; masked scan for recurrent/sliding-window stacks);
* slots are reused: more requests than slots all complete correctly;
* the fused ``lax.scan`` decode loop is token-identical to the seed-style
  per-step dispatch loop across exact/int8/sc modes;
* sampling: temperature draws are reproducible, top-k stays in the top-k.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.core.astra_layer import ComputeConfig
from repro.serve import (
    GREEDY, SamplerConfig, ServeConfig, ServeEngine, full_seq_packable,
    make_fused_decode, pack_prompts, packed_prefill, unfused_decode,
)
from repro.serve.sampling import sample_logits


def _model(arch, mode="exact", dtype="float32", **red):
    cfg = get_arch(arch).reduced(**red)
    cfg = dataclasses.replace(cfg, dtype=dtype)
    return Model(cfg, ModelOptions(cc=ComputeConfig(mode)))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    shape = (cfg.n_codebooks,) if cfg.n_codebooks else ()
    return [rng.integers(0, cfg.vocab, shape + (l,), dtype=np.int32) for l in lens]


def _per_request_greedy(model, params, prompt, gen, max_len):
    """Seed-style oracle: prompt through decode steps, then greedy argmax."""
    p = jnp.asarray(prompt)[None]
    states = model.init_decode_state(1, max_len)
    decode = jax.jit(model.decode)
    s0 = p.shape[-1]
    logits = None
    for t in range(s0):
        logits, states = decode(params, p[..., t : t + 1], states, jnp.int32(t))
    out = []
    for t in range(s0, s0 + gen):
        # per-codebook greedy: logits [B, 1, V] or [B, 1, C, V]
        ids = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        tok = ids[..., None] if model.cfg.n_codebooks else ids[:, None]
        out.append(np.asarray(tok[0]))
        logits, states = decode(params, tok, states, jnp.int32(t))
    return np.concatenate(out, axis=-1)


# --------------------------------------------------------- mixed lengths
@pytest.mark.parametrize("arch", ["stablelm-1.6b", "recurrentgemma-2b"])
def test_mixed_lengths_match_per_request(arch, key):
    """16/32/64-style mixed prompts in one running batch == per-request."""
    model = _model(arch, **({"window": 8} if get_arch(arch).window else {}))
    params = model.init(key)
    lens = (6, 11, 16)
    prompts = _prompts(model.cfg, lens)
    max_len = max(lens) + 10
    eng = ServeEngine(model, params,
                      ServeConfig(max_slots=3, max_len=max_len, chunk_steps=4))
    outs = eng.generate_batch(prompts, max_new_tokens=8)
    for p, o in zip(prompts, outs):
        ref = _per_request_greedy(model, params, p, 8, max_len)
        np.testing.assert_array_equal(o.tokens, ref)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "recurrentgemma-2b"])
def test_mixed_lengths_match_per_request_paged(arch, key):
    """Paged variant of the continuous-batching parity claim: the block
    pool + block-table indirection must be invisible to outputs (see
    tests/test_prefix_cache.py for the prefix-reuse claims)."""
    model = _model(arch, **({"window": 8} if get_arch(arch).window else {}))
    params = model.init(key)
    lens = (6, 11, 16)
    prompts = _prompts(model.cfg, lens)
    max_len = max(lens) + 10
    eng = ServeEngine(model, params,
                      ServeConfig(max_slots=3, max_len=max_len, chunk_steps=4,
                                  kv_block_size=8))
    outs = eng.generate_batch(prompts, max_new_tokens=8)
    for p, o in zip(prompts, outs):
        ref = _per_request_greedy(model, params, p, 8, max_len)
        np.testing.assert_array_equal(o.tokens, ref)


def test_window_larger_than_max_len(key):
    """Ring window > pre-allocated max_len: prefill must take the scan
    path (the full-seq pass emits window-sized rings that would not fit
    the clamped slotted cache)."""
    model = _model("recurrentgemma-2b")  # reduced keeps window=32
    assert model.cfg.window == 32
    params = model.init(key)
    prompts = _prompts(model.cfg, (5, 8))
    eng = ServeEngine(model, params,
                      ServeConfig(max_slots=2, max_len=20, chunk_steps=4))
    assert eng._force_scan_prefill
    outs = eng.generate_batch(prompts, max_new_tokens=6)
    for p, o in zip(prompts, outs):
        ref = _per_request_greedy(model, params, p, 6, 20)
        np.testing.assert_array_equal(o.tokens, ref)


def test_prefill_strategy_selection():
    attn_cfg = get_arch("stablelm-1.6b").reduced()
    rec_cfg = get_arch("recurrentgemma-2b").reduced(window=8)
    assert full_seq_packable(attn_cfg, [3, 5, 7])  # pure attention: pad-safe
    assert not full_seq_packable(rec_cfg, [3, 5, 7])  # recurrent: masked scan
    assert full_seq_packable(rec_cfg, [5, 5, 5])  # equal lengths: no padding


def test_packed_prefill_matches_single(key):
    """Packed mixed-length prefill logits == each prompt prefilled alone."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    prompts = _prompts(model.cfg, (4, 9))
    tokens, lengths = pack_prompts(prompts, model.cfg)
    last, _ = packed_prefill(model, params, tokens, lengths, 16,
                             lengths_static=[4, 9])
    for i, p in enumerate(prompts):
        t1, l1 = pack_prompts([p], model.cfg)
        last1, _ = packed_prefill(model, params, t1, l1, 16,
                                  lengths_static=[p.shape[-1]])
        np.testing.assert_allclose(np.asarray(last[i]), np.asarray(last1[0]),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ slot reuse
def test_slot_reuse_more_requests_than_slots(key):
    model = _model("stablelm-1.6b")
    params = model.init(key)
    lens = (5, 9, 7, 12, 4, 10)
    prompts = _prompts(model.cfg, lens)
    eng = ServeEngine(model, params,
                      ServeConfig(max_slots=2, max_len=32, chunk_steps=3))
    outs = eng.generate_batch(prompts, max_new_tokens=6)
    assert len(outs) == len(prompts)
    for p, o in zip(prompts, outs):
        assert o.gen_len == 6
        ref = _per_request_greedy(model, params, p, 6, 32)
        np.testing.assert_array_equal(o.tokens, ref)


def test_staggered_budgets_leave_at_step_granularity(key):
    """Different gen budgets: early finishers free their slot mid-stream."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    prompts = _prompts(model.cfg, (5, 5, 5))
    eng = ServeEngine(model, params,
                      ServeConfig(max_slots=2, max_len=32, chunk_steps=8))
    ids = [eng.submit(p, g) for p, g in zip(prompts, (2, 7, 5))]
    by_id = {o.request_id: o for o in eng.run()}
    for rid, g, p in zip(ids, (2, 7, 5), prompts):
        o = by_id[rid]
        assert o.gen_len == g
        ref = _per_request_greedy(model, params, p, g, 32)
        np.testing.assert_array_equal(o.tokens, ref)


def test_eos_stops_early(key):
    model = _model("stablelm-1.6b")
    params = model.init(key)
    [prompt] = _prompts(model.cfg, (6,))
    ref = _per_request_greedy(model, params, prompt, 12, 32)
    eos = int(ref[3])  # force a hit mid-stream
    eng = ServeEngine(model, params, ServeConfig(max_slots=1, max_len=32))
    [out] = eng.generate_batch([prompt], max_new_tokens=12, eos_id=eos)
    assert out.gen_len <= 12
    assert out.tokens[-1] == eos
    assert eos not in out.tokens[:-1]
    # EOS truncated a fused chunk: timing must count delivered tokens only
    assert out.timing.mean_itl_s >= 0.0
    assert out.wall_time_s >= out.timing.ttft_s


# ------------------------------------------- gather-free decode kernel
@pytest.mark.parametrize("arch", ["stablelm-1.6b", "recurrentgemma-2b"])
def test_paged_kernel_matches_sdpa_engine(arch, key):
    """attn_impl="flash" streams KV blocks through the block table
    (kernels/paged_attention) instead of gathering the logical view;
    the engine outputs must be token-identical across both global and
    windowed-ring paged layouts."""
    model = _model(arch, **({"window": 8} if get_arch(arch).window else {}))
    params = model.init(key)
    prompts = _prompts(model.cfg, (6, 11, 16))
    outs = {}
    for impl in ("naive", "flash"):
        eng = ServeEngine(model, params,
                          ServeConfig(max_slots=3, max_len=26, chunk_steps=4,
                                      kv_block_size=8, attn_impl=impl))
        outs[impl] = eng.generate_batch(prompts, 8)
    for a, b in zip(outs["naive"], outs["flash"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_dense_kernel_matches_sdpa_engine(key):
    """Dense layout: the length-masked decode kernel (and the flash
    full-sequence prefill) must be invisible to outputs too."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    prompts = _prompts(model.cfg, (6, 11, 16))
    outs = {}
    for impl in ("naive", "flash"):
        eng = ServeEngine(model, params,
                          ServeConfig(max_slots=3, max_len=26, chunk_steps=4,
                                      attn_impl=impl))
        outs[impl] = eng.generate_batch(prompts, 8)
    for a, b in zip(outs["naive"], outs["flash"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_chunked_prefill_with_kernel_matches_blocking(key):
    """Chunked suffix prefill under the streamed kernel: chunks resume at
    arbitrary in-block offsets, so this pins the causal paged-prefill
    kernel against blocking naive admission, token-identically."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    prompts = _prompts(model.cfg, (5, 19, 9))
    ref_eng = ServeEngine(model, params,
                          ServeConfig(max_slots=3, max_len=32, chunk_steps=4,
                                      kv_block_size=8))
    ref = ref_eng.generate_batch(prompts, 8)
    eng = ServeEngine(model, params,
                      ServeConfig(max_slots=3, max_len=32, chunk_steps=4,
                                  kv_block_size=8, attn_impl="flash",
                                  prefill_chunk_tokens=6))
    outs = eng.generate_batch(prompts, 8)
    for a, b in zip(ref, outs):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_serve_config_rejects_unknown_attn_impl(key):
    model = _model("stablelm-1.6b")
    params = model.init(key)
    with pytest.raises(ValueError, match="attn_impl"):
        ServeEngine(model, params, ServeConfig(max_slots=1, max_len=8,
                                               attn_impl="fused"))


# ------------------------------------------------- fused vs per-step loop
@pytest.mark.parametrize("mode", ["exact", "int8", "sc"])
@pytest.mark.parametrize("sampler", [GREEDY, SamplerConfig(0.8, 5)],
                         ids=["greedy", "topk"])
def test_fused_scan_matches_dispatch_loop(mode, sampler, key):
    model = _model("stablelm-1.6b", mode=mode)
    params = Model(model.cfg, ModelOptions()).init(key)
    b, s0, steps = 3, 4, 6
    tok = jax.random.randint(key, (b, 1), 0, model.cfg.vocab, jnp.int32)
    pos = jnp.full((b,), s0, jnp.int32)
    states = model.init_decode_state(b, 24)
    fused = make_fused_decode(model)
    toks_f, _, _ = fused(params, tok, states, pos, key, steps=steps, sampler=sampler)
    toks_u, _, _ = unfused_decode(model, params, tok, states, pos, key, steps, sampler)
    np.testing.assert_array_equal(np.asarray(toks_f), np.asarray(toks_u))


def test_per_slot_positions_match_scalar(key):
    """pos as [B] vector with equal entries == the scalar-pos decode path."""
    model = _model("stablelm-1.6b")
    params = model.init(key)
    b = 2
    tok = jax.random.randint(key, (b, 1), 0, model.cfg.vocab, jnp.int32)
    states = model.init_decode_state(b, 16)
    lg_s, st_s = model.decode(params, tok, states, jnp.int32(3))
    lg_v, st_v = model.decode(params, tok, states, jnp.full((b,), 3, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v), rtol=1e-6)
    for a, c in zip(jax.tree.leaves(st_s), jax.tree.leaves(st_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


# -------------------------------------------------------------- sampling
def test_sample_logits_greedy_and_topk(key):
    logits = jnp.asarray([[0.1, 3.0, -1.0, 2.0, 0.0]])
    assert int(sample_logits(logits, GREEDY, key)[0]) == 1
    draws = {int(sample_logits(logits, SamplerConfig(1.0, 2), jax.random.fold_in(key, i))[0])
             for i in range(50)}
    assert draws <= {1, 3}  # top-2 of the distribution
    same = [int(sample_logits(logits, SamplerConfig(1.0, 0), key)[0]) for _ in range(3)]
    assert len(set(same)) == 1  # same key -> same draw


def test_submit_validates_budget(key):
    model = _model("stablelm-1.6b")
    params = model.init(key)
    eng = ServeEngine(model, params, ServeConfig(max_slots=1, max_len=8))
    with pytest.raises(ValueError):
        eng.submit(np.zeros(6, np.int32), 6)


# ------------------------------------------------------------------- e2e
@pytest.mark.slow
@pytest.mark.parametrize("arch", ["stablelm-1.6b", "qwen1.5-0.5b", "xlstm-125m",
                                  "musicgen-large", "granite-moe-1b-a400m",
                                  "llama-3.2-vision-90b"])
def test_engine_e2e_archs(arch, key):
    """Long-running: mixed lengths + slot reuse across architecture families."""
    model = _model(arch)
    params = model.init(key)
    lens = (4, 9, 6, 12)
    prompts = _prompts(model.cfg, lens)
    eng = ServeEngine(model, params,
                      ServeConfig(max_slots=2, max_len=32, chunk_steps=4))
    outs = eng.generate_batch(prompts, max_new_tokens=8)
    for p, o in zip(prompts, outs):
        assert o.gen_len == 8
        assert o.hardware is not None and o.hardware.energy_j > 0
        ref = _per_request_greedy(model, params, p, 8, 32)
        np.testing.assert_array_equal(o.tokens, ref)
