"""Elastic scaling integration test (subprocess: 8 fake devices -> 4).

Simulates losing half the fleet mid-job: train 3 steps on a (2,2,2) mesh,
checkpoint, rebuild a (2,2) mesh from 4 surviving devices, elastic-restore
(re-shard every leaf), and run 2 more steps.  The loss trajectory after the
re-shard must continue exactly (global batch preserved; checkpoints are
mesh-agnostic full-logical arrays) — compared against an uninterrupted
8-device run of the same 5 steps.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent(
    """
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_arch
    from repro.data import DataConfig, SyntheticLMDataset
    from repro.launch.train import build_train_step
    from repro.models.model import Model
    from repro.models.transformer import ModelOptions
    from repro.optim import AdamWConfig, adamw_init
    from repro.parallel.sharding import activation_mesh, batch_specs, param_specs

    ckpt_dir = sys.argv[1]
    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = Model(cfg, ModelOptions())
    ocfg = AdamWConfig(lr=1e-3)
    ds = SyntheticLMDataset(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0))
    step_fn = build_train_step(model, ocfg, total_steps=5, warmup=1)

    def opt_shardings(mesh):
        shapes = jax.eval_shape(adamw_init, model.param_shapes())
        return {
            "m": param_specs(shapes["m"], mesh),
            "v": param_specs(shapes["v"], mesh),
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }

    def run_steps(mesh, params, opt, steps):
        p_sh = param_specs(model.param_shapes(), mesh)
        o_sh = opt_shardings(mesh)
        jit_step = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                           out_shardings=(p_sh, o_sh, None))
        losses = []
        for s in steps:
            batch = {"tokens": jnp.asarray(ds.batch_at(s)["tokens"])}
            b_sh = batch_specs(batch, mesh)
            batch = jax.tree.map(lambda a, sh: jax.device_put(a, sh), batch, b_sh)
            with mesh, activation_mesh(mesh):
                params, opt, m = jit_step(params, opt, batch)
            losses.append(float(m["loss"]))
        return params, opt, losses

    # --- uninterrupted 8-device reference run (5 steps) ---
    mesh8 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    p_sh8 = param_specs(model.param_shapes(), mesh8)
    with mesh8, activation_mesh(mesh8):
        params0 = jax.jit(model.init, out_shardings=p_sh8)(jax.random.PRNGKey(0))
        opt0 = adamw_init(params0)
    _, _, ref_losses = run_steps(mesh8, params0, opt0, range(5))

    # --- elastic run: 3 steps on 8 devices, checkpoint, resume on 4 ---
    with mesh8, activation_mesh(mesh8):
        params = jax.jit(model.init, out_shardings=p_sh8)(jax.random.PRNGKey(0))
        opt = adamw_init(params)
    params, opt, losses_a = run_steps(mesh8, params, opt, range(3))
    mgr = CheckpointManager(ckpt_dir, async_write=False)
    mgr.save(2, {"params": params, "opt": opt})

    # "pod loss": rebuild on the first 4 devices only
    mesh4 = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
    template = {"params": model.param_shapes(),
                "opt": jax.eval_shape(adamw_init, model.param_shapes())}
    shardings = {"params": param_specs(template["params"], mesh4),
                 "opt": opt_shardings(mesh4)}
    restored, _ = mgr.restore(2, template, shardings=shardings)
    params4, opt4 = restored["params"], restored["opt"]
    assert all(len(l.sharding.mesh.devices.flatten()) == 4
               for l in jax.tree.leaves(params4))
    _, _, losses_b = run_steps(mesh4, params4, opt4, range(3, 5))

    print(json.dumps({"ref": ref_losses, "elastic": losses_a + losses_b}))
    """
)


@pytest.mark.slow
def test_elastic_8_to_4_devices(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(rec["ref"]) == len(rec["elastic"]) == 5
    # pre-reshard steps are bit-identical; post-reshard steps agree to float
    # reduction-order noise (4-device collectives group sums differently
    # than 8-device ones — non-associative fp add, not an optimization drift)
    for a, b in zip(rec["ref"][:3], rec["elastic"][:3]):
        assert a == b, (rec["ref"], rec["elastic"])
    for a, b in zip(rec["ref"][3:], rec["elastic"][3:]):
        assert abs(a - b) < 1e-3, (rec["ref"], rec["elastic"])
