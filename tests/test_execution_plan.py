"""Per-site ExecutionPlan API.

The load-bearing claims:

* plan resolution: ordered glob rules (``|`` alternatives), first match
  wins, default fallback; scanned-layer groups must resolve consistently;
* the deprecation shim ``ModelOptions(cc=...)`` lowers to the uniform plan
  bit-identically (weight GEMMs under ``cc``; dynamic qk/pv and MoE
  router/expert GEMMs exact, as the pre-plan code always ran them);
* registry cross-check: every GEMM site the model executes resolves to
  exactly one simulator op-graph name, for every architecture in the zoo;
* a mixed plan (int8 attention qk/pv + sc static projections) runs
  end-to-end through the serve engine and matches per-request decoding;
* ``plan.calibrate`` bakes per-site activation scales that keep int8
  within the uniform-int8 accuracy tolerances;
* property: quantization against a calibrated static scale round-trips
  within half a quantization step for in-range values.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCHS, get_arch
from repro.core.astra_layer import ComputeConfig, EXACT, INT8
from repro.core.plan import (
    ExecutionPlan, PRESET_PLANS, model_sites, site_class, validate_site_registry,
)
from repro.core.quant import MAG_MAX, quantize
from repro.models.model import Model
from repro.models.transformer import ModelOptions, forward


# ---------------------------------------------------------------- resolution
def test_rules_first_match_wins_and_default():
    plan = ExecutionPlan.from_spec(
        {"*.qk|*.pv": "int8", "*_proj": "sc", "default": "exact"})
    assert plan.resolve("L0.attn.qk").mode == "int8"
    assert plan.resolve("L3.attn.pv").mode == "int8"
    assert plan.resolve("L0.attn.q_proj").mode == "sc"
    assert plan.resolve("L1.rglru.in_proj").mode == "sc"
    assert plan.resolve("L0.attn.up").mode == "exact"
    assert plan.resolve("lm_head").mode == "exact"
    # order matters: a broad early rule shadows later ones
    shadow = ExecutionPlan.from_spec({"L0.*": "int8", "*.qk": "sc"})
    assert shadow.resolve("L0.attn.qk").mode == "int8"
    assert shadow.resolve("L1.attn.qk").mode == "sc"


def test_from_spec_presets_modes_and_errors():
    assert ExecutionPlan.from_spec("int8") == ExecutionPlan.uniform(INT8)
    assert ExecutionPlan.from_spec("mixed") is PRESET_PLANS["mixed"]
    jplan = ExecutionPlan.from_spec('{"*.qk": "int8"}')
    assert jplan.resolve("L0.attn.qk").mode == "int8"
    with pytest.raises(ValueError) as e:
        ExecutionPlan.from_spec("bogus")
    msg = str(e.value)
    assert "mixed" in msg and "exact" in msg  # lists valid presets/modes
    with pytest.raises(ValueError):
        ExecutionPlan.from_spec("{not json")
    with pytest.raises(ValueError):
        ComputeConfig("fp7")  # helpful mode error, not a bare assert


def test_uniform_plan_keeps_dynamic_and_moe_sites_exact():
    """The legacy shim contract: the pre-plan global cc quantized only the
    dense() weight GEMMs — qk/pv and the MoE router/expert einsums always
    ran exact, so the uniform plan must pin them exact too."""
    plan = ExecutionPlan.uniform(INT8)
    assert plan.resolve("L0.attn.qk").mode == "exact"
    assert plan.resolve("L0.attn.pv").mode == "exact"
    assert plan.resolve("L0.attn.router").mode == "exact"
    assert plan.resolve("L0.attn.expert_up").mode == "exact"
    assert plan.resolve("L0.attn.expert_down").mode == "exact"
    assert plan.resolve("L0.attn.q_proj").mode == "int8"


def test_scanned_group_must_resolve_consistently():
    plan = ExecutionPlan.from_spec({"L0.*": "int8", "default": "exact"})
    with pytest.raises(ValueError, match="scanned trace"):
        plan.resolve_group(("L0.attn.qk", "L2.attn.qk"))
    # consistent groups pass
    assert plan.resolve_group(("L0.attn.qk", "L0.attn.pv")).mode == "int8"


def test_modeloptions_shim_lowers_cc_to_uniform_plan():
    legacy = ModelOptions(cc=INT8)
    modern = ModelOptions(plan="int8")
    assert legacy == modern and hash(legacy) == hash(modern)
    assert legacy.cc is None and legacy.plan == ExecutionPlan.uniform(INT8)


# ------------------------------------------------------- registry cross-check
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_every_executed_site_resolves_to_one_simulator_op(arch):
    """The acceptance cross-check: execution and the simulator share one
    op-naming scheme, 1:1 for every GEMM the model runs."""
    cfg = ARCHS[arch]
    validate_site_registry(cfg)  # raises on any mismatch
    assert len(set(model_sites(cfg))) == len(model_sites(cfg))  # unique ids


def test_site_class_strips_layer_index():
    assert site_class("L12.attn.qk") == "attn.qk"
    assert site_class("lm_head") == "lm_head"


# ----------------------------------------------------------------- execution
@pytest.fixture(scope="module")
def small():
    cfg = dataclasses.replace(get_arch("qwen1.5-0.5b").reduced(), dtype="float32")
    model = Model(cfg, ModelOptions())
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, cfg.vocab)
    return cfg, model, params, tokens


def test_shim_forward_bitwise_matches_plan_forward(small):
    cfg, _, params, tokens = small
    li, _, _ = forward(params, tokens, cfg, ModelOptions(cc=INT8))
    lp, _, _ = forward(params, tokens, cfg, ModelOptions(plan="int8"))
    np.testing.assert_array_equal(np.asarray(li), np.asarray(lp))


def test_mixed_plan_forward_tracks_exact(small):
    cfg, _, params, tokens = small
    lo, _, _ = forward(params, tokens, cfg, ModelOptions())
    lm, _, _ = forward(params, tokens, cfg, ModelOptions(plan="mixed"))
    lo, lm = np.asarray(lo, np.float32), np.asarray(lm, np.float32)
    rel = np.linalg.norm(lm - lo) / np.linalg.norm(lo)
    assert rel < 0.15, rel  # same bar as the uniform-int8 accuracy test
    assert (lm.argmax(-1) == lo.argmax(-1)).mean() > 0.9


def test_mixed_plan_serve_engine_end_to_end(small, key):
    """int8 qk/pv + sc projections through the continuous-batching engine.

    With *dynamic* activation scales, quantized numerics depend on batch
    composition (per-tensor amax over whatever shares the dispatch), so a
    batched engine cannot match per-request decoding token-for-token.
    Calibration is what restores request-level determinism: static per-site
    scales make every GEMM row-independent, so the engine under a
    *calibrated* mixed plan must be token-identical to per-request greedy
    decoding under the same plan."""
    from repro.serve import ServeConfig, ServeEngine
    from repro.serve.prefill import pack_prompts

    cfg, model, params, _ = small
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,),
                                             0, cfg.vocab), np.int32)
               for i, l in enumerate((5, 9))]
    cal_tokens, _ = pack_prompts(prompts, cfg)
    mixed = model.with_plan("mixed").calibrate(params, {"tokens": cal_tokens})
    assert mixed.plan.act_scales  # calibration actually observed sites
    eng = ServeEngine(model, params, ServeConfig(max_slots=2, max_len=24,
                                                 chunk_steps=3), plan=mixed.plan)
    outs = eng.generate_batch(prompts, max_new_tokens=6)
    decode = jax.jit(mixed.decode)
    for p, o in zip(prompts, outs):
        assert o.gen_len == 6
        assert o.hardware is not None and dict(o.hardware.energy_by_site)
        states = mixed.init_decode_state(1, 24)
        logits = None
        t = jnp.asarray(p)[None]
        for i in range(p.shape[-1]):
            logits, states = decode(params, t[:, i:i + 1], states, jnp.int32(i))
        ref = []
        for i in range(p.shape[-1], p.shape[-1] + 6):
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            ref.append(int(tok[0, 0]))
            logits, states = decode(params, tok, states, jnp.int32(i))
        np.testing.assert_array_equal(o.tokens, np.asarray(ref, np.int32))


# --------------------------------------------------------------- calibration
def test_calibrate_bakes_per_site_scales(small):
    cfg, model, params, tokens = small
    cal = model.with_plan("int8").calibrate(params, {"tokens": tokens})
    scales = dict(cal.plan.act_scales)
    assert scales, "calibration observed no sites"
    assert set(scales) <= set(model_sites(cfg))
    assert all(s > 0 for s in scales.values())
    # resolution injects the static scale into quantized sites only
    some_site = next(iter(scales))
    assert cal.plan.resolve(some_site).act_scale == pytest.approx(scales[some_site])
    exact_plan = ExecutionPlan.uniform(EXACT)
    assert exact_plan.resolve(some_site).act_scale is None


def test_calibrated_int8_tracks_exact_within_uniform_tolerance(small):
    """Per-site calibrated int8 stays inside the tolerance the uniform-int8
    accuracy test (test_astra_modes) already enforces."""
    cfg, model, params, tokens = small
    lo, _, _ = forward(params, tokens, cfg, ModelOptions())
    cal = model.with_plan("int8").calibrate(params, {"tokens": tokens})
    lc, _, _ = forward(params, tokens, cfg, cal.opts)
    lo, lc = np.asarray(lo, np.float32), np.asarray(lc, np.float32)
    rel = np.linalg.norm(lc - lo) / np.linalg.norm(lo)
    assert rel < 0.15, rel
    assert (lc.argmax(-1) == lo.argmax(-1)).mean() > 0.9


@settings(max_examples=25)
@given(st.integers(1, 10_000), st.integers(0, 2**31 - 1))
def test_calibrated_quant_roundtrip_property(amax_milli, seed):
    """Quantizing against a calibrated static scale round-trips within half
    a quantization step for every in-range value (the per-site PTQ
    contract the serving path relies on)."""
    amax = amax_milli / 1000.0
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-amax, amax, size=(64,)), jnp.float32)
    scale = amax / MAG_MAX  # what ExecutionPlan.calibrate bakes per site
    qt = quantize(x, axis=None, scale=scale)
    err = np.abs(np.asarray(qt.dequantize()) - np.asarray(x))
    assert err.max() <= scale / 2 + 1e-7


# ------------------------------------------------------------------ CLI gate
def test_cli_rejects_bad_plan_with_helpful_message(capsys):
    from repro.launch.serve import main

    with pytest.raises(SystemExit):
        main(["--plan", "bogus-plan"])
    err = capsys.readouterr().err
    assert "mixed" in err and "int8" in err  # lists valid presets/modes
