"""Docs-consistency checks: the documentation must not drift from the code.

* Every fenced ```json snippet in docs/PLANS.md / README.md must build a
  valid ``ExecutionPlan`` via ``from_spec``, and every glob rule in it
  must match at least one real site in the model zoo.
* Every inline-code site id quoted anywhere in the docs
  (``L3.attn.qk``-shaped, or ``lm_head``) must exist in some zoo config's
  ``model_sites``.
* Every relative markdown link in every *.md must resolve to a file.
"""
import json
import os
import re

import pytest

from repro.configs import ARCHS
from repro.core.plan import ExecutionPlan, _match, kv_sites, model_sites

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_DOC_FILES = [
    os.path.join(ROOT, name)
    for name in ("README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md",
                 "docs/SERVING.md", "docs/PLANS.md", "docs/ANALYSIS.md")
    if os.path.exists(os.path.join(ROOT, name))
]
_PLAN_DOCS = [p for p in _DOC_FILES if p.endswith(("PLANS.md", "README.md"))]


def _read(path):
    with open(path, encoding="utf-8") as f:
        return f.read()


# externally sourced material (arxiv extractions, exemplar snippets) may
# reference assets that were never retrieved — not ours to fix
_LINKCHECK_EXCLUDE = ("PAPER.md", "PAPERS.md", "SNIPPETS.md")


def _all_md_files():
    out = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", "artifacts", ".github")]
        out += [os.path.join(dirpath, f) for f in filenames
                if f.endswith(".md") and f not in _LINKCHECK_EXCLUDE]
    return out


def _fenced_blocks(text, lang):
    return re.findall(rf"```{lang}\n(.*?)```", text, flags=re.S)


@pytest.fixture(scope="module")
def zoo_sites():
    """Union of every executed GEMM site across the full (non-reduced) zoo,
    plus the KV storage sites (``L{li}.kv.{k,v}`` — not GEMMs, but the
    docs quote them by the same grammar; docs/PLANS.md §KV storage
    sites)."""
    sites = set()
    for cfg in ARCHS.values():
        sites.update(model_sites(cfg))
        sites.update(kv_sites(cfg))
    return sites


# ------------------------------------------------------------ plan snippets
def test_quoted_plan_json_snippets_build_plans(zoo_sites):
    checked = 0
    for path in _PLAN_DOCS:
        for block in _fenced_blocks(_read(path), "json"):
            spec = json.loads(block)  # must be valid JSON
            plan = ExecutionPlan.from_spec(spec)
            for pattern, _cc in plan.rules:
                assert any(_match(pattern, s) for s in zoo_sites), (
                    f"{os.path.relpath(path, ROOT)}: plan rule {pattern!r} "
                    "matches no site in the model zoo"
                )
            checked += 1
    assert checked >= 2, "expected plan JSON snippets in docs/PLANS.md"


def test_inline_plan_specs_in_shell_snippets(zoo_sites):
    """--plan '<json>' examples inside sh blocks must be valid specs too."""
    checked = 0
    for path in _PLAN_DOCS:
        for spec in re.findall(r"--plan '(\{.*?\})'", _read(path)):
            plan = ExecutionPlan.from_spec(spec)
            for pattern, _cc in plan.rules:
                assert any(_match(pattern, s) for s in zoo_sites)
            checked += 1
    assert checked >= 1


# ---------------------------------------------------------------- site ids
_SITE_RE = re.compile(r"^(?:L\d+\.[a-z]+\.[a-z0-9_]+|lm_head)$")


def test_quoted_site_ids_exist(zoo_sites):
    checked = 0
    for path in _DOC_FILES:
        for span in re.findall(r"`([^`\n]+)`", _read(path)):
            if _SITE_RE.match(span):
                assert span in zoo_sites, (
                    f"{os.path.relpath(path, ROOT)} quotes site {span!r} "
                    "which no zoo config executes"
                )
                checked += 1
    assert checked >= 3, "expected concrete site ids quoted in the docs"


# -------------------------------------------------------- benchmark schema
_SUMMARY_SECTION_KEYS = {"name", "headline_metric", "headline_value",
                         "claim_pass", "unix_time", "failed"}


def _bench_files():
    out = {}
    for fname in sorted(os.listdir(ROOT)):
        m = re.match(r"BENCH_([a-z0-9_]+)\.json$", fname)
        if m and m.group(1) != "summary":
            out[m.group(1)] = os.path.join(ROOT, fname)
    return out


def test_bench_summary_schema():
    """BENCH_summary.json is the cross-PR perf index: stable schema_version
    plus one entry per section with the full key set, covering every
    per-section BENCH_*.json committed at the repo root."""
    path = os.path.join(ROOT, "BENCH_summary.json")
    assert os.path.exists(path), "BENCH_summary.json missing at repo root"
    data = json.loads(_read(path))
    assert data.get("schema_version") == 1, "summary schema_version must be 1"
    sections = data.get("sections")
    assert isinstance(sections, dict) and sections, "summary has no sections"
    for name, entry in sections.items():
        missing = _SUMMARY_SECTION_KEYS - entry.keys()
        assert not missing, f"section {name!r} missing keys {sorted(missing)}"
        assert entry["name"] == name
        if entry["headline_value"] is not None:
            assert isinstance(entry["headline_value"], (int, float))
        if entry["claim_pass"] is not None:
            assert isinstance(entry["claim_pass"], bool)
    for name, bench_path in _bench_files().items():
        result = json.loads(_read(bench_path))  # must be valid JSON
        assert name in sections, (
            f"BENCH_{name}.json exists at the repo root but the summary "
            "index has no section for it — run `python -m benchmarks.run "
            f"--only {name}` so the trajectory stays complete"
        )
        if isinstance(result, dict) and "claim_pass" in result:
            assert isinstance(result["claim_pass"], bool), (
                f"BENCH_{name}.json claim_pass must be a bool"
            )


# ------------------------------------------------------------------- links
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def test_no_dead_relative_links():
    dead = []
    for path in _all_md_files():
        base = os.path.dirname(path)
        for target in _LINK_RE.findall(_read(path)):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if rel and not os.path.exists(os.path.join(base, rel)):
                dead.append(f"{os.path.relpath(path, ROOT)} -> {target}")
    assert not dead, "dead relative links:\n" + "\n".join(dead)
