"""Host-side paged-KV bookkeeping: block pool + radix prefix tree.

No JAX here — these pin the allocator/refcount/eviction protocol the
serve engine builds on (docs/SERVING.md).  The property test drives
random interleaved admit/finish/intern/evict sequences and checks blocks
are never leaked or double-freed.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.serve.kv_pool import KVBlockPool
from repro.serve.prefix_tree import RadixPrefixTree


# ------------------------------------------------------------------ pool
def test_pool_alloc_free_roundtrip():
    pool = KVBlockPool(n_blocks=8, block_size=4)
    assert pool.n_free == 7  # block 0 is scratch
    a = pool.alloc(3)
    assert len(set(a)) == 3 and 0 not in a
    assert pool.n_free == 4 and pool.n_live == 3
    for b in a:
        pool.decref(b)
    assert pool.n_free == 7 and pool.n_live == 0


def test_pool_refcount_shared_block():
    pool = KVBlockPool(8, 4)
    [b] = pool.alloc(1)
    pool.incref(b)  # second holder
    pool.decref(b)
    assert pool.n_free == 6  # still held
    pool.decref(b)
    assert pool.n_free == 7


def test_pool_errors():
    pool = KVBlockPool(4, 2)
    with pytest.raises(RuntimeError):
        pool.alloc(4)  # only 3 allocatable
    [b] = pool.alloc(1)
    pool.decref(b)
    with pytest.raises(ValueError):
        pool.decref(b)  # double free
    with pytest.raises(ValueError):
        pool.incref(b)  # incref on free block
    with pytest.raises(ValueError):
        pool.incref(0)  # scratch is not ref-counted
    with pytest.raises(ValueError):
        KVBlockPool(1, 4)
    with pytest.raises(ValueError):
        KVBlockPool(8, 0)


# ------------------------------------------------------------------ tree
def _toks(*vals):
    return np.asarray(vals, np.int32)


def test_tree_match_is_block_aligned():
    pool = KVBlockPool(16, 4)
    tree = RadixPrefixTree(block_size=4)
    blocks = pool.alloc(2)
    tree.insert(_toks(*range(8)), blocks, pool)
    assert [pool.ref(b) for b in blocks] == [2, 2]  # slot + tree
    # full match, capped match, partial-block divergence (no match there)
    assert tree.match(_toks(*range(8)), max_blocks=2) == blocks
    assert tree.match(_toks(*range(8)), max_blocks=1) == blocks[:1]
    assert tree.match(_toks(0, 1, 2, 3, 9, 9, 9, 9), max_blocks=2) == blocks[:1]
    assert tree.match(_toks(9, 1, 2, 3), max_blocks=1) == []
    # 7 tokens only cover one full block
    assert tree.match(_toks(*range(7)), max_blocks=2) == blocks[:1]


def test_tree_insert_dedups_existing_prefix():
    pool = KVBlockPool(16, 4)
    tree = RadixPrefixTree(4)
    first = pool.alloc(2)
    dup = pool.alloc(2)
    assert tree.insert(_toks(*range(8)), first, pool) == 2
    # same tokens, different blocks: nothing adopted, originals kept
    assert tree.insert(_toks(*range(8)), dup, pool) == 0
    assert tree.match(_toks(*range(8)), 2) == first
    assert [pool.ref(b) for b in dup] == [1, 1]  # still slot-owned only


def test_tree_evict_lru_leaves_only():
    pool = KVBlockPool(16, 2)
    tree = RadixPrefixTree(2)
    a = pool.alloc(2)  # chain A: two nodes
    b = pool.alloc(1)  # chain B: one node
    tree.insert(_toks(0, 1, 2, 3), a, pool)
    tree.insert(_toks(9, 9), b, pool)
    for blk in a + b:  # slots finish: only tree refs remain
        pool.decref(blk)
    tree.match(_toks(9, 9), 1)  # touch B -> A's leaf is LRU
    freed = tree.evict(1, pool)
    assert freed == 1
    assert tree.match(_toks(0, 1, 2, 3), 2) == a[:1]  # leaf gone, parent kept
    # evicting more drains the rest, deepest-first, and frees the blocks
    assert tree.evict(10, pool) == 2
    assert pool.n_free == 15
    assert len(tree) == 0


def test_tree_pinned_blocks_are_not_evictable():
    pool = KVBlockPool(16, 2)
    tree = RadixPrefixTree(2)
    a = pool.alloc(1)
    tree.insert(_toks(0, 1), a, pool)
    # a live slot still holds the block (ref 2) -> nothing to evict
    assert tree.evict(1, pool) == 0
    pool.decref(a[0])
    assert tree.evict(1, pool) == 1


def test_tree_multi_codebook_keys():
    pool = KVBlockPool(16, 2)
    tree = RadixPrefixTree(2)
    grid = np.arange(8, dtype=np.int32).reshape(2, 4)  # [C=2, S=4]
    blocks = pool.alloc(2)
    tree.insert(grid, blocks, pool)
    assert tree.match(grid, 2) == blocks
    other = grid.copy()
    other[1, 1] = 99  # differs inside the first block
    assert tree.match(other, 2) == []


def test_tree_never_interns_scratch():
    pool = KVBlockPool(16, 2)
    tree = RadixPrefixTree(2)
    assert tree.insert(_toks(0, 1, 2, 3), [0, 0], pool) == 0
    assert len(tree) == 0


# -------------------------------------------------------------- property
@settings(max_examples=30)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=40))
def test_random_admit_finish_never_leaks_or_double_frees(ops):
    """Engine-shaped usage: interleaved admit (match + incref + evict +
    alloc + intern) and finish (decref) must keep every block's refcount
    equal to holders(tree + live slots), and draining everything must
    return the pool to fully free."""
    bs, w, n_slots = 4, 4, 3
    pool = KVBlockPool(1 + n_slots * w + 2, bs)
    tree = RadixPrefixTree(bs)
    rng = np.random.default_rng(1234)
    live = {}  # slot id -> list of blocks
    interned = {}  # block -> True (mirror of tree adoption)

    def rebuild_interned():
        interned.clear()
        stack = [tree.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is not tree.root:
                interned[node.block] = True

    def check_refs():
        holders = {}
        for blocks in live.values():
            for b in blocks:
                holders[b] = holders.get(b, 0) + 1
        for b in interned:
            holders[b] = holders.get(b, 0) + 1
        for b in range(1, pool.n_blocks):
            assert pool.ref(b) == holders.get(b, 0), f"block {b} refcount drift"

    next_slot = 0
    for op in ops:
        if op <= 3 and len(live) < n_slots:  # admit
            prompt_len = int(rng.integers(1, w * bs - 1))
            total = -(-(prompt_len + 1) // bs)
            prompt = rng.integers(0, 3, prompt_len).astype(np.int32)
            matched = tree.match(prompt, max_blocks=min((prompt_len - 1) // bs, total))
            for b in matched:
                pool.incref(b)
            need = total - len(matched)
            if need > pool.n_free:
                tree.evict(need - pool.n_free, pool)
                rebuild_interned()
            blocks = matched + pool.alloc(need)
            live[next_slot] = blocks
            nb_full = prompt_len // bs
            if nb_full > len(matched):
                tree.insert(prompt[: nb_full * bs], blocks[:nb_full], pool)
                rebuild_interned()
            next_slot += 1
        elif live:  # finish the oldest slot
            sid = min(live)
            for b in live.pop(sid):
                pool.decref(b)
        check_refs()

    for blocks in live.values():
        for b in blocks:
            pool.decref(b)
    live.clear()
    tree.evict(pool.n_blocks, pool)
    assert pool.n_free == pool.n_blocks - 1, "leaked blocks"
    assert len(tree) == 0


# ------------------------------------------------------- byte accounting
def test_bytes_accounting_tracks_refcounts():
    """``bytes_per_block`` (stamped by the engine from the device pools —
    int8 under kv_quant) drives all serve-side KV byte accounting; the
    derived totals must follow the refcounts exactly."""
    pool = KVBlockPool(10, 4)
    assert pool.total_bytes == 0 and pool.live_bytes == 0  # unstamped
    pool.bytes_per_block = 256
    assert pool.total_bytes == 9 * 256  # block 0 is the scratch sink
    ids = pool.alloc(3)
    assert pool.live_bytes == 3 * 256
    pool.incref(ids[0])
    assert pool.live_bytes == 3 * 256  # extra refs don't double-count
    pool.decref(ids[0])
    pool.decref(ids[0])
    pool.decref(ids[1])
    assert pool.live_bytes == 1 * 256
    assert pool.total_bytes == 9 * 256  # capacity is refcount-independent
