"""Traffic generation, replay determinism, and SLO scoring
(docs/SERVING.md §Traffic, SLOs, and backpressure).

The load-bearing claims:

* trace generation is a pure function of its arguments — bit-identical
  arrivals, prompts, and budgets across calls, with no wall clock in
  the generator — and traces round-trip through JSON;
* arrival processes hit their offered rate (Poisson in expectation,
  bursty with the same mean but clustered), monotonically;
* shared-prefix scenarios draw their prefixes from a fixed pool, so
  prefix reuse survives across traces with different seeds;
* a virtual-clock replay through a fresh engine + front-end stack is
  fully deterministic: identical token streams, identical latency
  trajectories, identical SLO metrics across runs;
* the SLO evaluator's arithmetic: percentiles, rejection accounting,
  attainment and goodput.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import (
    FrontendConfig, RequestOutput, RequestTiming, ServeConfig, ServeEngine,
    ServeFrontend,
)
from repro.traffic import (
    SUITES, Scenario, SLOConfig, TrafficTrace, VirtualClock, bursty_arrivals,
    evaluate, generate_trace, parse_trace_spec, poisson_arrivals, replay_trace,
    trace_max_len,
)


# ------------------------------------------------------------- arrivals
def test_poisson_arrivals_deterministic_and_calibrated():
    a = poisson_arrivals(4.0, 4000, np.random.default_rng(1))
    b = poisson_arrivals(4.0, 4000, np.random.default_rng(1))
    assert np.array_equal(a, b)
    assert np.all(np.diff(a) >= 0)
    # mean rate within 10% at n=4000
    assert 4000 / a[-1] == pytest.approx(4.0, rel=0.1)


def test_bursty_arrivals_same_mean_rate_but_clustered():
    rng = np.random.default_rng(2)
    t = bursty_arrivals(8.0, 4096, rng, burst_size=8)
    assert len(t) == 4096 and np.all(np.diff(t) >= 0)
    assert 4096 / t[-1] == pytest.approx(8.0, rel=0.15)
    # bursts: most inter-arrival gaps are exactly zero
    assert np.mean(np.diff(t) == 0) > 0.5


def test_arrival_validation():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="rate_rps=0"):
        poisson_arrivals(0, 4, rng)
    with pytest.raises(ValueError, match="burst_size=0"):
        bursty_arrivals(1.0, 4, rng, burst_size=0)


# ------------------------------------------------------------ scenarios
def test_scenario_validation():
    with pytest.raises(ValueError, match="prompt_lens"):
        Scenario("s", prompt_lens=(), gen_lens=(4,))
    with pytest.raises(ValueError, match="shared_prefix_len 8"):
        Scenario("s", prompt_lens=(8,), gen_lens=(4,), shared_prefix_len=8)
    with pytest.raises(ValueError, match="weight"):
        Scenario("s", prompt_lens=(8,), gen_lens=(4,), weight=0)


def test_agent_suite_shares_prefixes_across_seeds():
    scen = SUITES["agent"][0]
    t1 = generate_trace("agent", 2.0, 16, seed=1, vocab=64)
    t2 = generate_trace("agent", 2.0, 16, seed=99, vocab=64)
    pre1 = {r.prompt[: scen.shared_prefix_len].tobytes() for r in t1.requests}
    pre2 = {r.prompt[: scen.shared_prefix_len].tobytes() for r in t2.requests}
    # the prefix pool is seeded by the *scenario*, not the trace: both
    # traces draw from the same n_prefixes prefixes
    assert pre1 == pre2 and len(pre1) <= scen.n_prefixes


# ---------------------------------------------------------------- trace
def test_trace_generation_deterministic():
    t1 = generate_trace("mixed", 3.0, 32, seed=5, vocab=64)
    t2 = generate_trace("mixed", 3.0, 32, seed=5, vocab=64)
    assert len(t1) == len(t2) == 32
    for a, b in zip(t1.requests, t2.requests):
        assert a.arrival_s == b.arrival_s
        assert np.array_equal(a.prompt, b.prompt)
        assert a.max_new_tokens == b.max_new_tokens and a.scenario == b.scenario
    t3 = generate_trace("mixed", 3.0, 32, seed=6, vocab=64)
    assert any(not np.array_equal(a.prompt, b.prompt)
               for a, b in zip(t1.requests, t3.requests))


def test_trace_json_roundtrip(tmp_path):
    t = generate_trace("chat", 2.0, 8, seed=0, vocab=64)
    path = str(tmp_path / "trace.json")
    t.save(path)
    back = TrafficTrace.load(path)
    assert back.suite == t.suite and len(back) == len(t)
    for a, b in zip(t.requests, back.requests):
        assert a.arrival_s == b.arrival_s and np.array_equal(a.prompt, b.prompt)
    # the file is plain JSON (inspectable, diffable)
    with open(path) as f:
        assert json.load(f)["suite"] == "chat"


def test_parse_trace_spec():
    kw = parse_trace_spec("longdoc:rate=2.5,n=64,seed=9,arrival=bursty")
    assert kw == {"suite": "longdoc", "rate_rps": 2.5, "n": 64, "seed": 9,
                  "arrival": "bursty"}
    assert parse_trace_spec("chat")["rate_rps"] == 1.0  # defaults
    with pytest.raises(ValueError, match="unknown suite"):
        parse_trace_spec("nope:rate=1")
    with pytest.raises(ValueError, match="unknown trace spec key"):
        parse_trace_spec("chat:bogus=1")
    with pytest.raises(ValueError, match="unknown arrival process"):
        parse_trace_spec("chat:arrival=warp")


# ------------------------------------------------------------------ SLO
def _out(rid, ttft, max_itl, mean_itl=None, reject=None, gen=4, queue=0.0):
    timing = RequestTiming(queue_time_s=queue, ttft_s=ttft, wall_time_s=ttft,
                           mean_itl_s=mean_itl if mean_itl is not None else max_itl,
                           max_itl_s=max_itl, n_token_events=gen)
    toks = np.zeros((0 if reject else gen,), np.int32)
    return RequestOutput(rid, np.zeros((4,), np.int32), toks,
                         wall_time_s=ttft, timing=timing, reject_reason=reject)


def test_slo_evaluate_arithmetic():
    outs = [_out(0, 0.1, 0.01), _out(1, 0.2, 0.05),
            _out(2, 0.9, 0.01),             # TTFT violation
            _out(3, 0.1, 0.50),             # ITL violation
            _out(4, 0.0, 0.0, reject="queue_full", queue=0.3),
            _out(5, 0.0, 0.0, reject="queue_timeout", queue=2.0)]
    m = evaluate(outs, duration_s=10.0, slo=SLOConfig(ttft_s=0.5, itl_s=0.1),
                 offered_rps=0.6)
    assert m["n_offered"] == 6 and m["n_completed"] == 4 and m["n_rejected"] == 2
    assert m["rejected_by_reason"] == {"queue_full": 1, "queue_timeout": 1}
    assert m["rejection_rate"] == pytest.approx(2 / 6)
    assert m["n_slo_met"] == 2
    assert m["slo_attainment"] == pytest.approx(2 / 6)
    assert m["goodput_rps"] == pytest.approx(0.2)
    assert m["completed_rps"] == pytest.approx(0.4)
    assert m["completed_tok_s"] == pytest.approx(1.6)
    assert m["ttft_p50_s"] == pytest.approx(np.percentile([0.1, 0.2, 0.9, 0.1], 50))
    assert m["itl_max_s"] == pytest.approx(0.5)


def test_slo_config_validation():
    with pytest.raises(ValueError, match="ttft_s=0"):
        SLOConfig(ttft_s=0, itl_s=1)
    with pytest.raises(ValueError, match="itl_s=-1"):
        SLOConfig(ttft_s=1, itl_s=-1)


def test_slo_empty_outputs():
    m = evaluate([], duration_s=1.0)
    assert m["n_offered"] == 0 and m["ttft_p99_s"] == 0.0


# ---------------------------------------------------------------- replay
def _model():
    cfg = dataclasses.replace(get_arch("stablelm-1.6b").reduced(),
                              dtype="float32")
    model = Model(cfg, ModelOptions(cc=ComputeConfig("exact")))
    return model, model.init(__import__("jax").random.PRNGKey(0))


def test_virtual_clock():
    clk = VirtualClock(2.0)
    assert clk() == clk.now() == 2.0
    clk.advance(0.5)
    assert clk() == 2.5
    with pytest.raises(ValueError, match="dt_s=-1"):
        clk.advance(-1)


def test_virtual_replay_requires_virtual_clock():
    model, params = _model()
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=64, astra_accounting=False))
    fe = ServeFrontend(eng, FrontendConfig())
    trace = generate_trace("chat", 4.0, 2, seed=0, vocab=model.cfg.vocab)
    with pytest.raises(ValueError, match="VirtualClock"):
        replay_trace(fe, trace, virtual_step_s=0.05)
    with pytest.raises(ValueError, match="virtual_step_s=-0.1"):
        replay_trace(fe, trace, virtual_step_s=-0.1)


def test_virtual_replay_deterministic_end_to_end():
    model, params = _model()
    trace = generate_trace("chat", 8.0, 10, seed=4, vocab=model.cfg.vocab)

    def run_once():
        clk = VirtualClock()
        eng = ServeEngine(model, params, ServeConfig(
            max_slots=2, max_len=trace_max_len(trace), chunk_steps=4,
            astra_accounting=False), clock=clk)
        fe = ServeFrontend(eng, FrontendConfig(max_queue_depth=4,
                                               queue_timeout_s=1.0), clock=clk)
        return replay_trace(fe, trace, virtual_step_s=0.05)

    r1, r2 = run_once(), run_once()
    assert r1.request_ids == r2.request_ids
    assert r1.duration_s == r2.duration_s
    o1, o2 = r1.outputs_by_id, r2.outputs_by_id
    assert set(o1) == set(o2) == set(r1.request_ids)
    for rid in r1.request_ids:
        assert o1[rid].reject_reason == o2[rid].reject_reason
        assert np.array_equal(o1[rid].tokens, o2[rid].tokens)
        # streamed chunks concatenate to the terminal tokens, identically
        assert np.array_equal(r1.token_streams[rid], r2.token_streams[rid])
        if o1[rid].reject_reason is None:
            assert np.array_equal(r1.token_streams[rid], o1[rid].tokens)
        else:
            assert r1.token_streams[rid].shape[-1] == 0
        if o1[rid].timing is not None:
            assert o1[rid].timing.ttft_s == o2[rid].timing.ttft_s
            assert o1[rid].timing.queue_time_s == o2[rid].timing.queue_time_s
    m1 = evaluate(r1.outputs, r1.duration_s, SLOConfig(0.5, 0.2))
    m2 = evaluate(r2.outputs, r2.duration_s, SLOConfig(0.5, 0.2))
    assert m1 == m2
    assert r1.stats == r2.stats


def test_overload_burst_bounded_and_accounted():
    model, params = _model()
    trace = generate_trace("chat", 50.0, 16, seed=3, vocab=model.cfg.vocab,
                           arrival="bursty", burst_size=8)
    clk = VirtualClock()
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=trace_max_len(trace), chunk_steps=4,
        astra_accounting=False), clock=clk)
    fe = ServeFrontend(eng, FrontendConfig(max_queue_depth=3,
                                           queue_timeout_s=0.4), clock=clk)
    r = replay_trace(fe, trace, virtual_step_s=0.05)
    st = r.stats
    # every offered request terminates exactly once, visibly
    assert len(r.outputs) == 16
    n_rej = st["rejected_queue_full"] + st["rejected_queue_timeout"]
    assert st["completed"] + n_rej == 16 and n_rej > 0
    assert st["max_queue_depth"] <= 3
    for o in r.outputs:
        if o.reject_reason is not None:
            assert o.gen_len == 0 and o.timing is not None
