"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bit-exactness.

Every kernel runs in interpret mode (CPU) and must match its ref.py oracle
exactly (integer kernels) or to fp tolerance (flash attention).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize
from repro.kernels.bts_encode.ops import bts_encode
from repro.kernels.bts_encode.ref import bts_encode_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.int8_matmul.ops import int8_matmul
from repro.kernels.int8_matmul.ref import int8_matmul_ref
from repro.kernels.paged_attention.ops import (
    dense_attention_decode, paged_attention_decode, paged_attention_prefill,
)
from repro.kernels.paged_attention.ref import (
    dense_decode_ref, paged_decode_ref, paged_prefill_ref,
)
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.stoch_matmul.ops import stoch_matmul, stoch_matmul_packed
from repro.kernels.stoch_matmul.ref import (
    encode_operands, stoch_matmul_packed_ref, stoch_matmul_ref,
)


# ------------------------------------------------------------- stoch_matmul
@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (16, 48, 8), (33, 17, 5), (64, 96, 32)])
def test_stoch_matmul_kernel_bit_exact(rng, m, k, n):
    xq = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    xs, sx, ws, sw = encode_operands(xq, wq)
    got = stoch_matmul_packed(xs, sx, ws, sw)
    want = stoch_matmul_packed_ref(xs, sx, ws, sw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("x_gen,w_gen", [("thermometer", "bresenham"), ("lfsr", "bresenham"), ("thermometer", "lfsr")])
def test_stoch_matmul_generators(rng, x_gen, w_gen):
    xq = quantize(jnp.asarray(rng.standard_normal((24, 40)), jnp.float32))
    wq = quantize(jnp.asarray(rng.standard_normal((40, 12)), jnp.float32), axis=0)
    got = stoch_matmul(xq, wq, x_gen=x_gen, w_gen=w_gen)
    want = stoch_matmul_ref(xq, wq, x_gen=x_gen, w_gen=w_gen)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (32, 32, 32)])
def test_stoch_matmul_blocking_invariance(rng, bm, bn, bk):
    """BlockSpec tiling must not change the result."""
    xq = jnp.asarray(rng.integers(-127, 128, (32, 32)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (32, 16)), jnp.int8)
    xs, sx, ws, sw = encode_operands(xq, wq)
    want = stoch_matmul_packed_ref(xs, sx, ws, sw)
    got = stoch_matmul_packed(xs, sx, ws, sw, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -------------------------------------------------------------- int8_matmul
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (64, 256, 32), (100, 70, 9)])
def test_int8_matmul_kernel(rng, m, k, n):
    xq = quantize(jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
    wq = quantize(jnp.asarray(rng.standard_normal((k, n)), jnp.float32), axis=0)
    got = int8_matmul(xq, wq)
    want = int8_matmul_ref(xq, wq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_int8_matmul_saturating_inputs():
    x = jnp.full((8, 16), 127, jnp.int8)
    from repro.core.quant import QTensor
    xq = QTensor(x, jnp.float32(1.0))
    wq = QTensor(-x.T.reshape(16, 8), jnp.float32(1.0))
    got = int8_matmul(xq, wq)
    want = int8_matmul_ref(xq, wq)  # -127*127*16 accumulations: needs int32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)


# ---------------------------------------------------------------- bts_encode
@pytest.mark.parametrize("gen", ["thermometer", "bresenham", "lfsr"])
@pytest.mark.parametrize("shape", [(64, 64), (65, 3), (7, 129)])
def test_bts_encode_kernel(rng, gen, shape):
    q = jnp.asarray(rng.integers(-127, 128, shape), jnp.int8)
    words, sign = bts_encode(q, generator=gen)
    words_ref, sign_ref = bts_encode_ref(q, generator=gen)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(words_ref))
    np.testing.assert_array_equal(np.asarray(sign), np.asarray(sign_ref))


# --------------------------------------------------------- paged attention
def _paged_setup(rng, b, kvh, g, hd, bs, w, n_blocks):
    q = jnp.asarray(rng.standard_normal((b, kvh * g, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_blocks, kvh, bs, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_blocks, kvh, bs, hd)), jnp.float32)
    table = jnp.asarray(rng.integers(1, n_blocks, (b, w)), jnp.int32)
    return q, kp, vp, table


@pytest.mark.parametrize("g", [1, 2, 4])
@pytest.mark.parametrize("softcap", [0.0, 5.0])
def test_paged_decode_kernel_vs_ref(rng, g, softcap):
    """Streamed decode vs the gathered-view oracle; one batch row per
    kv_len boundary: empty, single token, exact block edge, one past it,
    and the full table extent."""
    kvh, hd, bs, w = 2, 16, 4, 3
    kv_len = jnp.asarray([0, 1, bs, bs + 1, w * bs], jnp.int32)
    q, kp, vp, table = _paged_setup(rng, kv_len.shape[0], kvh, g, hd, bs, w, 16)
    got = paged_attention_decode(q, kp, vp, table, kv_len, softcap=softcap)
    want = paged_decode_ref(q, kp, vp, table, kv_len, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_decode_kernel_ring_layout(rng):
    """Windowed-ring layout: KV written through ``_paged_write_token`` in
    wrapped ring order must read back identically through the streamed
    kernel and the gathered ``_paged_view`` + ``_sdpa`` path."""
    from repro.models.attention import PagedKVCache, _paged_view, _paged_write_token, _sdpa

    b, kvh, g, hd, bs, ring_blocks = 2, 2, 2, 16, 4, 2
    ring = ring_blocks * bs
    cache = PagedKVCache(jnp.zeros((8, kvh, bs, hd)), jnp.zeros((8, kvh, bs, hd)))
    table = jnp.asarray([[1, 2], [5, 3]], jnp.int32)
    # write past the wrap point: positions 0..ring+2 land at slot pos % ring
    for pos in range(ring + 3):
        kn = jnp.asarray(rng.standard_normal((b, kvh, 1, hd)), jnp.float32)
        vn = jnp.asarray(rng.standard_normal((b, kvh, 1, hd)), jnp.float32)
        cache = _paged_write_token(cache, table, jnp.full((b,), pos % ring, jnp.int32), kn, vn)
    kv_len = jnp.full((b,), ring, jnp.int32)  # ring full: every slot valid
    q = jnp.asarray(rng.standard_normal((b, kvh * g, hd)), jnp.float32)
    got = paged_attention_decode(q, cache.k, cache.v, table, kv_len)
    k_log, v_log = _paged_view(cache, table)
    want = _sdpa(q[:, :, None], k_log, v_log, causal=False, window=0, kv_len=kv_len)[:, :, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("g,softcap", [(1, 0.0), (2, 3.0)])
def test_paged_prefill_kernel_vs_ref(rng, g, softcap):
    """Causal suffix prefill: starts at 0, mid-block, and block edges."""
    kvh, hd, bs, w, s = 2, 16, 4, 4, 3
    start = jnp.asarray([0, 2, bs - 1, bs, 2 * bs + 1], jnp.int32)
    q, kp, vp, table = _paged_setup(rng, start.shape[0], kvh, g, hd, bs, w, 24)
    qs = jnp.asarray(rng.standard_normal((start.shape[0], kvh * g, s, hd)), jnp.float32)
    got = paged_attention_prefill(qs, kp, vp, table, start, softcap=softcap)
    want = paged_prefill_ref(qs, kp, vp, table, start, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_dense_decode_kernel_vs_ref(rng):
    """Length-masked dense decode, incl. a partial trailing key block
    (S not a multiple of bk) and per-slot kv_len boundaries."""
    kvh, g, hd, sk = 2, 2, 16, 11
    kv_len = jnp.asarray([0, 1, 4, 5, 11], jnp.int32)
    b = kv_len.shape[0]
    q = jnp.asarray(rng.standard_normal((b, kvh * g, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kvh, sk, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kvh, sk, hd)), jnp.float32)
    got = dense_attention_decode(q, k, v, kv_len, bk=4)
    want = dense_decode_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_decode_kernel_bf16(rng):
    kvh, g, hd, bs, w = 2, 2, 16, 4, 3
    kv_len = jnp.asarray([3, 9], jnp.int32)
    q, kp, vp, table = _paged_setup(rng, 2, kvh, g, hd, bs, w, 8)
    q, kp, vp = (x.astype(jnp.bfloat16) for x in (q, kp, vp))
    got = paged_attention_decode(q, kp, vp, table, kv_len)
    assert got.dtype == jnp.bfloat16
    want = paged_decode_ref(q.astype(jnp.float32), kp, vp, table, kv_len)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want), atol=0.05)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("sq,sk,causal,window", [
    (128, 128, True, 0),
    (256, 256, True, 64),
    (130, 130, True, 0),     # padding path
    (64, 64, True, 16),
])
def test_flash_attention_vs_ref(rng, sq, sk, causal, window):
    b, h, d = 2, 2, 32
    q = jnp.asarray(rng.standard_normal((b, h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, sk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, sk, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    want = attention_ref(
        q.reshape(b * h, sq, d), k.reshape(b * h, sk, d), v.reshape(b * h, sk, d),
        scale=d ** -0.5, causal=causal, window=window,
    ).reshape(b, h, sq, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("hq,hkv,s,window", [
    (8, 2, 128, 0),
    (4, 1, 64, 16),   # window + fold
    (6, 3, 72, 0),    # folded rows (g*s=144) not a block multiple: pad path
])
def test_flash_attention_gqa(rng, hq, hkv, s, window):
    """Hq != Hkv runs group-folded (no repeated K/V): the kernel must
    recover true query positions through the fold period."""
    b, d = 2, 16
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64)
    kr = jnp.repeat(k, hq // hkv, axis=1).reshape(b * hq, s, d)
    vr = jnp.repeat(v, hq // hkv, axis=1).reshape(b * hq, s, d)
    want = attention_ref(q.reshape(b * hq, s, d), kr, vr, scale=d ** -0.5,
                         causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got).reshape(b * hq, s, d), np.asarray(want), atol=2e-5)


def test_flash_attention_softcap(rng):
    """Logit softcap (tanh(s/c)*c, pre-mask) must match the _sdpa order."""
    from repro.models.attention import _sdpa

    b, h, s, d, cap = 1, 2, 64, 16, 4.0
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, softcap=cap, bq=64, bk=64)
    want = _sdpa(q, k, v, causal=True, window=0, softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16(rng):
    b, h, s, d = 1, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True)
    want = attention_ref(
        *(x.astype(jnp.float32).reshape(b * h, s, d) for x in (q, k, v)),
        scale=d ** -0.5, causal=True,
    ).reshape(b, h, s, d)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=0.05
    )


# ------------------------------------------------------------------- rglru
@pytest.mark.parametrize("b,s,d,chunk", [(2, 64, 16, 16), (3, 100, 8, 32), (1, 16, 4, 64)])
def test_rglru_scan_kernel(rng, b, s, d, chunk):
    a = jnp.asarray(rng.uniform(0.2, 0.999, (b, s, d)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    got = rglru_scan(a, x, chunk=chunk)
    want = rglru_scan_ref(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
