"""Open-loop serving front-end (docs/SERVING.md §Traffic, SLOs, and
backpressure).

The load-bearing claims:

* per-token streaming is token-identical and exactly-once vs the batch
  ``run()`` path — on the dense layout, under the paged KV cache with
  prefix reuse, and under the chunked-prefill scheduler;
* finished requests can be drained mid-stream without disturbing the
  streams still in flight, and outputs are handed over exactly once;
* admission control is visible: queue-full and queue-timeout rejections
  produce terminal outputs with ``reject_reason`` and queue-wait-only
  timing (nothing silently vanishes), and the waiting line's high-water
  mark respects ``max_queue_depth``;
* ``Request.t_submit`` anchors at *front-end* admission, so time spent
  under backpressure shows up in ``RequestTiming.queue_time_s``;
* the config surface rejects nonsense values with the offending value
  in the message.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.astra_layer import ComputeConfig
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.serve import (
    REJECT_QUEUE_FULL, REJECT_QUEUE_TIMEOUT, FrontendConfig, ServeConfig,
    ServeEngine, ServeFrontend,
)
from repro.traffic import VirtualClock


def _model(arch="stablelm-1.6b", mode="exact", **red):
    cfg = get_arch(arch).reduced(**red)
    cfg = dataclasses.replace(cfg, dtype="float32")
    return Model(cfg, ModelOptions(cc=ComputeConfig(mode)))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    shape = (cfg.n_codebooks,) if cfg.n_codebooks else ()
    return [rng.integers(0, cfg.vocab, shape + (l,), dtype=np.int32)
            for l in lens]


@pytest.fixture(scope="module")
def model_params(key):
    model = _model()
    return model, model.init(key)


@pytest.fixture(scope="module")
def key():
    import jax

    return jax.random.PRNGKey(0)


def _stack(model, params, fe_cfg=FrontendConfig(), clock=None, **serve_kw):
    serve_kw.setdefault("max_slots", 4)
    serve_kw.setdefault("max_len", 96)
    serve_kw.setdefault("chunk_steps", 4)
    eng = ServeEngine(model, params, ServeConfig(
        astra_accounting=False, **serve_kw), clock=clock)
    return ServeFrontend(eng, fe_cfg, clock=clock)


# ------------------------------------------------------------- streaming
@pytest.mark.parametrize("serve_kw", [
    {},  # dense per-slot layout
    {"kv_block_size": 16, "prefix_cache": True},  # paged + prefix cache
    {"kv_block_size": 16, "prefill_chunk_tokens": 32},  # chunked prefill
], ids=["dense", "paged_prefix", "chunked_prefill"])
def test_stream_token_identical_to_run(model_params, serve_kw):
    model, params = model_params
    lens, gen = [7, 16, 16, 31], 12
    prompts = _prompts(model.cfg, lens)

    # reference: batch path on a fresh engine
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=4, max_len=96, chunk_steps=4, astra_accounting=False,
        **serve_kw))
    ref = {o.request_id: o.tokens
           for o in eng.generate_batch(prompts, gen)}

    fe = _stack(model, params, **serve_kw)
    streams = [fe.stream(p, gen) for p in prompts]
    for s, (rid, want) in zip(streams, sorted(ref.items())):
        toks = list(s)  # pumps on demand
        assert s.finished and s.output is not None
        got = (np.stack(toks, axis=-1) if toks
               else np.zeros(want.shape[:-1] + (0,), np.int32))
        assert np.array_equal(got, want)
        assert np.array_equal(s.output.tokens, want)
    # exactly-once: outputs drain once, then never again
    outs = fe.drain()
    assert sorted(o.request_id for o in outs) == [s.request_id for s in streams]
    assert fe.drain() == [] and fe.run() == []


def test_callback_matches_stream(model_params):
    model, params = model_params
    fe = _stack(model, params)
    [prompt] = _prompts(model.cfg, [9])
    chunks = []
    rid = fe.submit(prompt, 10, on_tokens=chunks.append)
    outs = fe.run()
    assert [o.request_id for o in outs] == [rid]
    assert np.array_equal(np.concatenate(chunks, axis=-1), outs[0].tokens)
    # chunked delivery, not one blob per token nor one call at the end
    assert sum(c.shape[-1] for c in chunks) == 10


def test_mid_stream_drain_of_finished_request(model_params):
    model, params = model_params
    short, long_ = _prompts(model.cfg, [8, 8])
    fe = _stack(model, params)
    s_short = fe.stream(short, 2)
    s_long = fe.stream(long_, 24)
    long_toks = []
    while not s_short.finished:
        long_toks.append(next(s_long))
    # the short request finished mid-stream: drain it now, exactly once
    drained = fe.drain()
    assert [o.request_id for o in drained] == [s_short.request_id]
    assert np.array_equal(
        np.stack(list(s_short), axis=-1) if s_short.output.gen_len else
        np.zeros((0,), np.int32), s_short.output.tokens)
    long_toks.extend(s_long)
    assert np.array_equal(np.stack(long_toks, axis=-1), s_long.output.tokens)
    remaining = fe.drain()
    assert [o.request_id for o in remaining] == [s_long.request_id]


def test_stream_gen_len_zero(model_params):
    model, params = model_params
    fe = _stack(model, params)
    [p] = _prompts(model.cfg, [5])
    s = fe.stream(p, 0)
    assert s.finished and s.output.gen_len == 0
    assert list(s) == []
    assert [o.request_id for o in fe.drain()] == [s.request_id]


def test_eos_trimmed_stream_matches_output(model_params):
    model, params = model_params
    # pick the greedy model's own next token as EOS so it fires mid-gen
    [p] = _prompts(model.cfg, [11])
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=96, chunk_steps=4, astra_accounting=False))
    [ref] = eng.generate_batch([p], 16)
    eos = int(np.asarray(ref.tokens).reshape(-1)[3])  # a token it will emit
    fe = _stack(model, params)
    s = fe.stream(p, 16, eos_id=eos)
    toks = list(s)
    assert np.array_equal(np.stack(toks, axis=-1), s.output.tokens)
    if s.output.gen_len < 16:  # EOS actually hit: stream ends exactly there
        assert int(np.asarray(toks[-1]).reshape(-1)[0]) == eos


# ------------------------------------------------------------- rejection
def test_queue_full_rejection_is_visible(model_params):
    model, params = model_params
    clk = VirtualClock()
    fe = _stack(model, params,
                FrontendConfig(max_queue_depth=1, max_concurrency=1),
                clock=clk, max_slots=1)
    prompts = _prompts(model.cfg, [6, 6, 6])
    rids = [fe.submit(p, 4) for p in prompts]
    # slot 1 in flight, slot 2 waiting, slot 3 over the bound -> rejected
    rejected = fe.drain()
    assert [o.request_id for o in rejected] == [rids[2]]
    assert rejected[0].reject_reason == REJECT_QUEUE_FULL
    assert rejected[0].gen_len == 0
    assert rejected[0].timing is not None
    served = fe.run()
    assert sorted(o.request_id for o in served) == rids[:2]
    assert all(o.reject_reason is None for o in served)
    st = fe.stats
    assert st["rejected_queue_full"] == 1 and st["completed"] == 2
    assert st["max_queue_depth"] <= 1


def test_queue_timeout_rejection_counts_wait(model_params):
    model, params = model_params
    clk = VirtualClock()
    fe = _stack(model, params,
                FrontendConfig(max_concurrency=1, queue_timeout_s=0.5),
                clock=clk, max_slots=1)
    blocker, waiter = _prompts(model.cfg, [6, 6])
    rid_b = fe.submit(blocker, 8)
    rid_w = fe.submit(waiter, 8)
    clk.advance(0.75)  # past the timeout while still queued
    fe.pump()
    outs = fe.drain()
    by_id = {o.request_id: o for o in outs}
    assert by_id[rid_w].reject_reason == REJECT_QUEUE_TIMEOUT
    assert by_id[rid_w].timing.queue_time_s == pytest.approx(0.75)
    rest = fe.run()
    assert rid_b in {o.request_id for o in outs} | {o.request_id for o in rest}
    assert fe.stats["rejected_queue_timeout"] == 1


def test_queue_wait_anchored_at_frontend_submit(model_params):
    model, params = model_params
    clk = VirtualClock()
    fe = _stack(model, params, FrontendConfig(max_concurrency=1),
                clock=clk, max_slots=1)
    first, second = _prompts(model.cfg, [6, 6])
    fe.submit(first, 6)
    rid2 = fe.submit(second, 6)
    # hold the second request at the front-end while the first serves
    while fe.stats["queue_depth"]:
        clk.advance(0.05)
        fe.pump()
    outs = fe.run()
    out2 = next(o for o in outs if o.request_id == rid2)
    # its measured queue time covers the *front-end* wait, not just the
    # engine-internal admission gap
    assert out2.timing.queue_time_s >= 0.05


def test_rejected_stream_is_terminal(model_params):
    model, params = model_params
    fe = _stack(model, params,
                FrontendConfig(max_queue_depth=0, max_concurrency=1),
                max_slots=1)
    a, b = _prompts(model.cfg, [6, 6])
    s_ok = fe.stream(a, 4)
    s_no = fe.stream(b, 4)
    assert s_no.finished and s_no.output.reject_reason == REJECT_QUEUE_FULL
    assert list(s_no) == []
    assert np.array_equal(np.stack(list(s_ok), axis=-1), s_ok.output.tokens)


# ------------------------------------------------------------ validation
def test_frontend_config_validation():
    with pytest.raises(ValueError, match="max_queue_depth=-1"):
        FrontendConfig(max_queue_depth=-1)
    with pytest.raises(ValueError, match="queue_timeout_s=0"):
        FrontendConfig(queue_timeout_s=0)
    with pytest.raises(ValueError, match="queue_timeout_s=-2.5"):
        FrontendConfig(queue_timeout_s=-2.5)
    with pytest.raises(ValueError, match="max_concurrency=0"):
        FrontendConfig(max_concurrency=0)


def test_max_concurrency_capped_by_slots(model_params):
    model, params = model_params
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=2, max_len=64, astra_accounting=False))
    with pytest.raises(ValueError, match="max_concurrency=5"):
        ServeFrontend(eng, FrontendConfig(max_concurrency=5))


def test_engine_submit_validation(model_params):
    model, params = model_params
    eng = ServeEngine(model, params, ServeConfig(
        max_slots=1, max_len=16, astra_accounting=False))
    shape = ((model.cfg.n_codebooks, 0) if model.cfg.n_codebooks else (0,))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros(shape, np.int32), 4)
    [p] = _prompts(model.cfg, [8])
    with pytest.raises(ValueError, match="max_new_tokens=-1"):
        eng.submit(p, -1)
    with pytest.raises(ValueError):
        eng.submit(p, 100)  # 8 + 100 > max_len=16
