"""End-to-end training: loss decreases; resume is bit-exact; MoE balance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch.train import build_train_step
from repro.models.model import Model
from repro.models.transformer import ModelOptions
from repro.optim import AdamWConfig, adamw_init


def _run(arch="qwen1.5-0.5b", steps=25, seed=0, fail_resume_at=None, tmp_path=None):
    cfg = get_arch(arch).reduced()
    model = Model(cfg, ModelOptions())
    ocfg = AdamWConfig(lr=2e-3)
    # low-entropy task so a tiny model shows clear learning within ~25 steps
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4, seed=seed,
                      menu_size=4, greedy_p=0.95, copy_len=16)
    ds = SyntheticLMDataset(dcfg)
    step_fn = jax.jit(build_train_step(model, ocfg, total_steps=steps, warmup=5))
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    losses = []
    ckpt = None
    for s in range(steps):
        if fail_resume_at is not None and s == fail_resume_at:
            # simulate failure + restore from the snapshot taken earlier
            params, opt = jax.tree.map(jnp.asarray, ckpt)
        batch = ds.batch_at(s)
        params, opt, m = step_fn(params, opt, {"tokens": jnp.asarray(batch["tokens"])})
        losses.append(float(m["loss"]))
        if fail_resume_at is not None and s == fail_resume_at - 1 and ckpt is None:
            ckpt = jax.tree.map(np.asarray, (params, opt))
    return losses


def test_loss_decreases():
    losses = _run(steps=25)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.05, (first, last)


def test_deterministic_across_runs():
    a = _run(steps=6)
    b = _run(steps=6)
    np.testing.assert_array_equal(a, b)


def test_moe_trains():
    losses = _run(arch="granite-moe-1b-a400m", steps=15)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


@pytest.mark.slow
def test_driver_fault_recovery_matches_clean_run(tmp_path):
    """The full train driver: a fault at step 17 with ckpt-every 10 must
    reproduce the fault-free trajectory (step-addressable data + atomic
    checkpoints => bit-exact replay)."""
    from repro.launch.train import main

    clean = main([
        "--arch", "qwen1.5-0.5b", "--reduced", "--steps", "24", "--batch", "2",
        "--seq", "32", "--ckpt-every", "8", "--ckpt-dir", str(tmp_path / "a"),
    ])
    faulty = main([
        "--arch", "qwen1.5-0.5b", "--reduced", "--steps", "24", "--batch", "2",
        "--seq", "32", "--ckpt-every", "8", "--ckpt-dir", str(tmp_path / "b"),
        "--fail-at", "17",
    ])
    assert faulty["restarts"] == 1
    for s, m in clean["metrics"].items():
        assert abs(faulty["metrics"][s]["loss"] - m["loss"]) < 1e-6, s
