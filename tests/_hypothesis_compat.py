"""``hypothesis`` with a deterministic fallback shim.

The property tests declare ``hypothesis`` in pyproject.toml, but hermetic
test environments may not have it installed.  When the real library is
available it is used unchanged; otherwise this module provides the tiny
subset the suite needs (``given``/``settings`` and the ``integers`` /
``sampled_from`` / ``lists`` strategies) backed by a seeded PRNG, so the
property tests still execute instead of failing collection.
"""
try:  # pragma: no cover - prefer the real thing
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:
    import functools
    import random

    _SEED = 0xA57A  # deterministic: same examples every run

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def lists(elem, min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 10
            return _Strategy(
                lambda r: [elem.draw(r) for _ in range(r.randint(min_size, hi))]
            )

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(_SEED)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strats]
                    fn(*args, *drawn, **kwargs)

            # hide the wrapped signature: pytest must not mistake the
            # drawn parameters for fixtures
            del wrapper.__wrapped__
            return wrapper

        return deco
