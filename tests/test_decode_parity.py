"""Prefill/forward vs token-by-token decode consistency.

The strongest end-to-end correctness check we have: for each architecture
family, feeding tokens one at a time through ``decode_step`` (KV caches,
ring buffers, recurrent states) must reproduce the logits of the full
``forward`` pass at every position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import Model
from repro.models.transformer import ModelOptions

# families: dense GQA / local+rglru hybrid / sLSTM+mLSTM / audio codebooks /
# cross-attn VLM / MoE
PARITY_ARCHS = [
    "stablelm-1.6b",           # partial rope + layernorm
    "qwen1.5-0.5b",            # qkv bias
    "qwen1.5-110b",            # GQA kv<heads (reduced)
    "qwen2.5-32b",             # GQA + bias
    "recurrentgemma-2b",       # rglru + local attention ring buffer
    "xlstm-125m",              # mlstm + slstm states
    "musicgen-large",          # multi-codebook audio grid
    "llama-3.2-vision-90b",    # cross-attention layers
    "qwen3-moe-30b-a3b",       # 128e top-8 MoE (reduced)
    "granite-moe-1b-a400m",    # MoE (full_capacity decode path)
]


def _inputs(cfg, b, s, key):
    if cfg.n_codebooks:
        tokens = jax.random.randint(key, (b, cfg.n_codebooks, s), 0, cfg.vocab)
    else:
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    vis = None
    if cfg.vision_tokens:
        vis = jax.random.normal(key, (b, cfg.vision_tokens, cfg.d_model), jnp.float32)
    return tokens, vis


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_forward(arch, key):
    import dataclasses

    cfg = get_arch(arch).reduced()
    if cfg.window:
        cfg = get_arch(arch).reduced(window=8)  # exercise ring wrap: s > window
    # fp32 params avoid bf16 accumulation mismatches between the two paths
    cfg = dataclasses.replace(cfg, dtype="float32")
    # MoE: decode is deliberately drop-free (full capacity); give the
    # forward pass a drop-free capacity factor too so parity isolates the
    # routing/combine math from the (documented) drop-policy difference.
    opts = ModelOptions(capacity_factor=float(cfg.moe.n_experts)) if cfg.moe else ModelOptions()
    model = Model(cfg, opts)
    params = model.init(key)
    b, s = 2, 20
    tokens, vis = _inputs(cfg, b, s, key)

    batch = {"tokens": tokens}
    if vis is not None:
        batch["vision_embeds"] = vis
    from repro.models.transformer import forward

    full_logits, _, _ = forward(params, tokens, cfg, model.opts, vision_embeds=vis)

    states = model.init_decode_state(b, max_len=s + 1)
    if vis is not None:
        states = _prime_xattn_states(model, params, states, vis, cfg)
    got = []
    for t in range(s):
        tok_t = tokens[..., t : t + 1]
        logits_t, states = model.decode(params, tok_t, states, jnp.int32(t))
        got.append(logits_t)
    got = jnp.concatenate(got, axis=1)

    g = np.asarray(got, np.float32)
    w = np.asarray(full_logits, np.float32)
    assert g.shape == w.shape
    np.testing.assert_allclose(g, w, atol=0.06, rtol=0.02)


def _prime_xattn_states(model, params, states, vis, cfg):
    """Cross-attention caches hold the (static) frontend KV: prefill once."""
    _, primed = model.prefill(params, {"tokens": jnp.zeros((vis.shape[0], 1), jnp.int32),
                                       "vision_embeds": vis})

    # copy only the xattn KV (static) leaves; keep zeroed self-attn caches
    def merge(init_leaf, primed_leaf):
        if init_leaf.shape == primed_leaf.shape:
            return primed_leaf
        return init_leaf

    import jax as _jax
    return _jax.tree.map(merge, states, primed)


def test_greedy_generation_deterministic(key):
    """Same prompt + params -> identical greedy continuations across runs."""
    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = Model(cfg, ModelOptions())
    params = model.init(key)
    prompt = jax.random.randint(key, (1, 8), 0, cfg.vocab)

    def gen():
        states = model.init_decode_state(1, 32)
        logits = None
        for t in range(8):
            logits, states = model.decode(params, prompt[:, t : t + 1], states, jnp.int32(t))
        outs = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for t in range(8, 16):
            outs.append(int(tok[0, 0]))
            logits, states = model.decode(params, tok, states, jnp.int32(t))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return outs

    assert gen() == gen()
